//! FM-style gain-cached local search over tagged move classes.
//!
//! The shuffle-based [`super::NcNeighborhood`] re-evaluates the whole pair
//! set round after round even though a swap of `(u, v)` can only change the
//! gain of moves touching `u`, `v` or one of their communication neighbors
//! (the invariant tested by
//! `objective::tests::moves_touch_only_endpoints_and_neighbors`).
//! [`GainCacheNc`] exploits that: it evaluates every move once, keeps the
//! gains in a max-priority bucket queue, and after each applied move
//! re-activates *only* the moves incident to a vertex the move touched —
//! the k-way FM machinery of *High-Quality Hierarchical Process Mapping*
//! (arXiv:2001.07134) on this paper's neighborhoods.
//!
//! The queue is **move-class generic**: entries are tagged
//! [`MoveRef::Swap`] (a pair of the `N_C^d` set) or [`MoveRef::Rotate3`]
//! (one direction of a communication-graph triangle,
//! [`super::comm_triangles`]). Pair-only it is the spec grammar's
//! `gc:nc<d>`; with rotations ([`GainCacheNc::with_rotations`], spec
//! `gc:nccyc<d>`) the *same queue* pops the best of swap or 3-cycle
//! rotation — a high-gain rotation no longer waits behind pair-swap
//! convergence the way the phased [`super::NcCycle`] parks it. Two CSR
//! incidence indexes (vertex → pairs, vertex → triangles) make
//! re-activation exact for both classes.
//!
//! Invalidation is lazy: queue entries carry no gain, only the move id;
//! each move stamps the move versions of its endpoints
//! ([`Swapper::version_of`]) at evaluation time, and a popped move is
//! re-evaluated only when a stamp went stale. Engines without version
//! tracking (the dense Table-1 baseline) fall back to the refiner's own
//! applied-move epoch — every pop after a move re-evaluates, which costs
//! extra evaluations but follows the *identical* move trajectory (a
//! re-evaluated untouched move returns its cached gain, so queue order
//! never diverges; tested below). Stamps are full u64: the former fallback
//! truncated the epoch to u32, so after 2^32 applied moves two distinct
//! epochs aliased and could resurrect a stale gain.
//!
//! Unlike the shuffle search, which stops after a probabilistic failure
//! streak, the queue drains exactly when no queued move improves: the
//! refiner terminates at a provable local optimum of the (union)
//! neighborhood — no improving pair in `N_C^d` *and*, with rotations, no
//! improving rotation in either direction of any triangle — and it never
//! consults the RNG: the trajectory is a pure function of the start mapping
//! (which is why `gc:nc<d>`/`gc:nccyc<d>` specs with deterministic
//! constructions short-circuit repetitions, see `api::MapJob`).

use super::cycle::TriangleSet;
use super::nc::nc_pairs;
use super::{graph_key, Refiner, SearchStats, Swapper};
use crate::graph::{Graph, NodeId};
use crate::util::{control, Rng, RunControl};

/// Gains at or above this clamp share the top bucket (and everything ≤ 0
/// lands in bucket 0). The clamp only coarsens the *search order* — the
/// local-optimum guarantee rests on "every possibly-improving move is
/// queued", never on exact ordering.
const GAIN_BUCKET_CAP: usize = 4096;

/// Upcoming queue entries the deterministic parallel drain pre-evaluates
/// per speculation round (scaled by the thread count).
const SPEC_BATCH_PER_THREAD: usize = 16;

/// Pops consumed between speculation rounds of the deterministic parallel
/// drain (scaled by the thread count). Larger windows amortize the scoped
/// thread spawn; smaller windows keep the side cache closer to the live
/// queue state.
const SPEC_WINDOW_PER_THREAD: usize = 8;

/// Candidates popped per free-running round (scaled by the thread count).
const FREE_BATCH_PER_THREAD: usize = 32;

/// Max-priority bucket queue over move ids. `O(1)` push, amortized
/// `O(1)` pop (the top cursor only rescans buckets emptied since the last
/// high-priority push); LIFO within a bucket, so the whole structure is
/// deterministic.
#[derive(Debug, Clone, Default)]
pub struct GainBucketQueue {
    /// `buckets[b]` holds the move ids whose priority clamps to `b`.
    buckets: Vec<Vec<u32>>,
    /// Upper bound on the highest non-empty bucket.
    top: usize,
    len: usize,
}

impl GainBucketQueue {
    pub fn new() -> GainBucketQueue {
        GainBucketQueue::default()
    }

    /// Bucket of a gain value (clamped into `0..=GAIN_BUCKET_CAP`).
    #[inline]
    fn bucket_of(gain: i64) -> usize {
        gain.clamp(0, GAIN_BUCKET_CAP as i64) as usize
    }

    /// Remove everything, keeping the allocated bucket storage.
    pub fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.top = 0;
        self.len = 0;
    }

    /// Queue move `id` at priority `gain`.
    pub fn push(&mut self, id: u32, gain: i64) {
        let b = Self::bucket_of(gain);
        if b >= self.buckets.len() {
            self.buckets.resize_with(b + 1, Vec::new);
        }
        self.buckets[b].push(id);
        if b > self.top {
            self.top = b;
        }
        self.len += 1;
    }

    /// Pop a move id from the highest non-empty bucket.
    pub fn pop(&mut self) -> Option<u32> {
        loop {
            if let Some(p) = self.buckets.get_mut(self.top).and_then(|b| b.pop()) {
                self.len -= 1;
                return Some(p);
            }
            if self.top == 0 {
                return None;
            }
            self.top -= 1;
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The next (up to) `k` move ids in exact pop order — highest bucket
    /// first, LIFO within a bucket — without removing anything. The
    /// deterministic parallel drain peeks the upcoming entries, evaluates
    /// the stale ones read-only on worker threads, and lets the untouched
    /// pop loop consume the results: queue state never changes here, so
    /// the pop sequence is exactly the sequential one.
    pub fn peek_upcoming(&self, k: usize, out: &mut Vec<u32>) {
        out.clear();
        if k == 0 || self.len == 0 {
            return;
        }
        let mut b = self.top.min(self.buckets.len().saturating_sub(1));
        loop {
            if let Some(bucket) = self.buckets.get(b) {
                for &id in bucket.iter().rev() {
                    out.push(id);
                    if out.len() == k {
                        return;
                    }
                }
            }
            if b == 0 {
                return;
            }
            b -= 1;
        }
    }
}

/// The canonical pair set of `N_C^d` plus a CSR incidence index
/// (vertex → indices of the pairs it participates in), keyed by the graph
/// fingerprint and distance it was built for.
#[derive(Debug, Clone)]
struct PairIndex {
    key: (usize, usize, u64),
    d: u32,
    pairs: Vec<(NodeId, NodeId)>,
    /// Row offsets into [`Self::inc`], length `n + 1`.
    inc_off: Vec<u32>,
    /// Concatenated incidence lists, length `2 * pairs.len()`.
    inc: Vec<u32>,
}

impl PairIndex {
    fn build(comm: &Graph, d: u32, key: (usize, usize, u64)) -> PairIndex {
        let pairs = nc_pairs(comm, d);
        let n = comm.n();
        let mut inc_off = vec![0u32; n + 1];
        for &(u, v) in &pairs {
            inc_off[u as usize + 1] += 1;
            inc_off[v as usize + 1] += 1;
        }
        for i in 0..n {
            inc_off[i + 1] += inc_off[i];
        }
        let mut cursor = inc_off.clone();
        let mut inc = vec![0u32; pairs.len() * 2];
        for (i, &(u, v)) in pairs.iter().enumerate() {
            inc[cursor[u as usize] as usize] = i as u32;
            cursor[u as usize] += 1;
            inc[cursor[v as usize] as usize] = i as u32;
            cursor[v as usize] += 1;
        }
        PairIndex { key, d, pairs, inc_off, inc }
    }

    /// Indices of the pairs with endpoint `x`.
    #[inline]
    fn incident(&self, x: NodeId) -> &[u32] {
        &self.inc[self.inc_off[x as usize] as usize..self.inc_off[x as usize + 1] as usize]
    }
}

/// CSR incidence index over the canonical triangle set (vertex → indices
/// of the triangles it participates in), the rotation-class mirror of
/// [`PairIndex`]. Holds only the incidence — the triangle coordinates
/// themselves live once, in the refiner's shared [`TriangleSet`] cache
/// (the same type [`super::Cycle3`] caches its canonical set in), and are
/// read from there at decode time.
#[derive(Debug, Clone)]
struct TriIndex {
    key: (usize, usize, u64),
    /// Row offsets into [`Self::inc`], length `n + 1`.
    inc_off: Vec<u32>,
    /// Concatenated incidence lists, length `3 * |triangles|`.
    inc: Vec<u32>,
}

impl TriIndex {
    fn build(n: usize, tris: &[(NodeId, NodeId, NodeId)], key: (usize, usize, u64)) -> TriIndex {
        let mut inc_off = vec![0u32; n + 1];
        for &(u, v, w) in tris {
            inc_off[u as usize + 1] += 1;
            inc_off[v as usize + 1] += 1;
            inc_off[w as usize + 1] += 1;
        }
        for i in 0..n {
            inc_off[i + 1] += inc_off[i];
        }
        let mut cursor = inc_off.clone();
        let mut inc = vec![0u32; tris.len() * 3];
        for (i, &(u, v, w)) in tris.iter().enumerate() {
            for x in [u, v, w] {
                inc[cursor[x as usize] as usize] = i as u32;
                cursor[x as usize] += 1;
            }
        }
        TriIndex { key, inc_off, inc }
    }

    /// Indices of the triangles with corner `x`.
    #[inline]
    fn incident(&self, x: NodeId) -> &[u32] {
        &self.inc[self.inc_off[x as usize] as usize..self.inc_off[x as usize + 1] as usize]
    }
}

/// A tagged move in the unified queue. Move ids pack both classes into one
/// `u32` space: ids `< np` are the pairs in `N_C^d` order; ids `≥ np` come
/// in (forward, reverse) couples per triangle — see [`decode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MoveRef {
    /// Swap the endpoints of pair `i` of the `N_C^d` pair set.
    Swap(usize),
    /// Rotate triangle `t`; `true` reverses the direction (`(u, w, v)`
    /// instead of `(u, v, w)` — the two are mutually inverse).
    Rotate3(usize, bool),
}

/// Decode a packed move id (`np` = number of pairs).
#[inline]
fn decode(id: usize, np: usize) -> MoveRef {
    if id < np {
        MoveRef::Swap(id)
    } else {
        let r = id - np;
        MoveRef::Rotate3(r >> 1, r & 1 == 1)
    }
}

/// Orient a canonical triangle for one rotation direction.
#[inline]
fn oriented(tri: (NodeId, NodeId, NodeId), rev: bool) -> (NodeId, NodeId, NodeId) {
    let (a, b, c) = tri;
    if rev {
        (a, c, b)
    } else {
        (a, b, c)
    }
}

/// Version stamp of move `id`'s endpoints: the engine's per-vertex move
/// versions when it tracks them, the refiner's applied-move epoch
/// otherwise. Full u64 throughout — the former fallback truncated the u64
/// epoch to u32, so epoch `2^32` aliased epoch `0` and a stale cached gain
/// could have passed the freshness check and been applied blind. Pair moves
/// leave the third slot 0. `tri_list` is the canonical triangle set (empty
/// when rotations are off — rotation ids are never decoded then).
#[inline]
fn stamp_of(
    engine: &dyn Swapper,
    versioned: bool,
    epoch: u64,
    pairs: &PairIndex,
    tri_list: &[(NodeId, NodeId, NodeId)],
    np: usize,
    id: usize,
) -> [u64; 3] {
    if !versioned {
        return [epoch; 3];
    }
    match decode(id, np) {
        MoveRef::Swap(p) => {
            let (u, v) = pairs.pairs[p];
            [engine.version_of(u), engine.version_of(v), 0]
        }
        MoveRef::Rotate3(t, rev) => {
            let (u, v, w) = oriented(tri_list[t], rev);
            [engine.version_of(u), engine.version_of(v), engine.version_of(w)]
        }
    }
}

/// Evaluate move `id`: its exact gain plus the stamp taken at evaluation
/// time (both read-only on the engine, so the two are consistent).
#[inline]
fn evaluate(
    engine: &dyn Swapper,
    versioned: bool,
    epoch: u64,
    pairs: &PairIndex,
    tri_list: &[(NodeId, NodeId, NodeId)],
    np: usize,
    id: usize,
) -> (i64, [u64; 3]) {
    let gain = match decode(id, np) {
        MoveRef::Swap(p) => {
            let (u, v) = pairs.pairs[p];
            engine.swap_gain(u, v)
        }
        MoveRef::Rotate3(t, rev) => {
            let (u, v, w) = oriented(tri_list[t], rev);
            engine.rotate3_gain(u, v, w)
        }
    };
    (gain, stamp_of(engine, versioned, epoch, pairs, tri_list, np, id))
}

/// Re-queue every move incident to `moved` or one of its communication
/// neighbors — exactly the moves whose gain the applied move may have
/// changed: swaps by pair incidence, both directions of every rotation by
/// triangle incidence. The cached gain is only the queue-priority hint; the
/// stale stamp forces a re-evaluation at pop time.
#[allow(clippy::too_many_arguments)]
fn activate(
    queue: &mut GainBucketQueue,
    queued: &mut [bool],
    gain: &[i64],
    pairs: &PairIndex,
    tris: Option<&TriIndex>,
    np: usize,
    comm: &Graph,
    moved: NodeId,
) {
    let mut touch = |x: NodeId| {
        for &p in pairs.incident(x) {
            let id = p as usize;
            if !queued[id] {
                queued[id] = true;
                queue.push(p, gain[id]);
            }
        }
        if let Some(ti) = tris {
            for &t in ti.incident(x) {
                let base = np + 2 * t as usize;
                for id in [base, base + 1] {
                    if !queued[id] {
                        queued[id] = true;
                        queue.push(id as u32, gain[id]);
                    }
                }
            }
        }
    };
    touch(moved);
    for &x in comm.neighbors(moved) {
        touch(x);
    }
}

/// Apply a fresh improving move and re-activate its neighborhood — the
/// shared tail of the sequential, speculative and free-running drains.
/// Also installs the negated-gain fresh-stamp shortcut: the applied pair's
/// own gain is exactly negated (swaps), and the inverse rotation direction
/// undoes a rotation exactly, so both re-activation pops drop
/// evaluation-free.
#[allow(clippy::too_many_arguments)]
fn apply_and_activate(
    engine: &mut dyn Swapper,
    comm: &Graph,
    pairs: &PairIndex,
    tris: Option<&TriIndex>,
    tri_list: &[(NodeId, NodeId, NodeId)],
    np: usize,
    queue: &mut GainBucketQueue,
    queued: &mut [bool],
    gain: &mut [i64],
    stamp: &mut [[u64; 3]],
    versioned: bool,
    improved: &mut u64,
    i: usize,
    g: i64,
) {
    match decode(i, np) {
        MoveRef::Swap(p) => {
            let (u, v) = pairs.pairs[p];
            engine.do_swap_with_gain(u, v, g);
            *improved += 1;
            gain[i] = -g;
            stamp[i] = stamp_of(&*engine, versioned, *improved, pairs, tri_list, np, i);
            for x in [u, v] {
                activate(queue, queued, gain, pairs, tris, np, comm, x);
            }
        }
        MoveRef::Rotate3(t, rev) => {
            let (u, v, w) = oriented(tri_list[t], rev);
            engine.do_rotate3_with_gain(u, v, w, g);
            *improved += 1;
            let inv = np + 2 * t + usize::from(!rev);
            gain[inv] = -g;
            stamp[inv] = stamp_of(&*engine, versioned, *improved, pairs, tri_list, np, inv);
            for x in [u, v, w] {
                activate(queue, queued, gain, pairs, tris, np, comm, x);
            }
        }
    }
}

/// The shared queue drain — free-running rounds (opt-in), the deterministic
/// speculative prefetch, and the sequential pop/apply loop — extracted from
/// `refine` so the warm-started REMAP path ([`GainCacheNc::refine_warm`])
/// resumes the *identical* loop after its partial re-seed. A free function
/// (not a method) because the caller holds a shared borrow of its own
/// `pairs`/`tris` fields while lending the queue and the gain/stamp/queued
/// arrays mutably — field-disjoint borrows that only split inside one
/// function body.
///
/// On return the queue is empty (a certified local optimum) unless
/// `stats.stopped` is set, in which case the remaining entries are left in
/// place and the engine sits at the last applied move — a valid anytime
/// mapping.
#[allow(clippy::too_many_arguments)]
fn drain(
    engine: &mut dyn Swapper,
    comm: &Graph,
    pairs: &PairIndex,
    tris: Option<&TriIndex>,
    tri_list: &[(NodeId, NodeId, NodeId)],
    np: usize,
    versioned: bool,
    threads: usize,
    free: bool,
    ctrl: &RunControl,
    queue: &mut GainBucketQueue,
    gain: &mut [i64],
    stamp: &mut [[u64; 3]],
    queued: &mut [bool],
    spec_gain: &mut Vec<i64>,
    spec_stamp: &mut Vec<[u64; 3]>,
    spec_valid: &mut Vec<bool>,
    stats: &mut SearchStats,
) {
    let nm = gain.len();
    let armed = ctrl.armed();

    // free-running parallel drain (opt-in): rounds of batched parallel
    // evaluation against the frozen pre-batch state, then in-order
    // applies revalidated per move against the live state. Applies may
    // interleave differently than the sequential drain — the
    // trajectory can diverge — but every applied move's gain is exact
    // at apply time, and activate() re-queues everything an apply may
    // have changed, so the sequential drain below (which then starts
    // from an empty or quiescent queue) still certifies the
    // union-neighborhood local optimum.
    if free && threads > 1 {
        let batch_cap = threads * FREE_BATCH_PER_THREAD;
        let mut batch: Vec<u32> = Vec::with_capacity(batch_cap);
        let mut results: Vec<(i64, [u64; 3])> = Vec::with_capacity(batch_cap);
        loop {
            // round boundary = move boundary: every apply below leaves a
            // valid mapping, so stopping between rounds is safe
            if armed {
                if let Some(r) = ctrl.stop_reason() {
                    stats.stopped = Some(r);
                    return;
                }
            }
            batch.clear();
            while batch.len() < batch_cap {
                let Some(id) = queue.pop() else { break };
                queued[id as usize] = false;
                batch.push(id);
            }
            if batch.is_empty() {
                break;
            }
            results.clear();
            results.resize(batch.len(), (0, [0; 3]));
            let chunk = batch.len().div_ceil(threads);
            {
                let eng: &dyn Swapper = &*engine;
                let epoch = stats.improved;
                std::thread::scope(|s| {
                    for (ids, out) in batch.chunks(chunk).zip(results.chunks_mut(chunk)) {
                        s.spawn(move || {
                            for (&id, slot) in ids.iter().zip(out.iter_mut()) {
                                *slot = evaluate(
                                    eng,
                                    versioned,
                                    epoch,
                                    pairs,
                                    tri_list,
                                    np,
                                    id as usize,
                                );
                            }
                        });
                    }
                });
            }
            for (k, &id) in batch.iter().enumerate() {
                let i = id as usize;
                let (g, st) = results[k];
                stats.evaluated += 1;
                gain[i] = g;
                stamp[i] = st;
                if g <= 0 {
                    continue;
                }
                let now = stamp_of(&*engine, versioned, stats.improved, pairs, tri_list, np, i);
                if st == now {
                    apply_and_activate(
                        &mut *engine,
                        comm,
                        pairs,
                        tris,
                        tri_list,
                        np,
                        queue,
                        queued,
                        gain,
                        stamp,
                        versioned,
                        &mut stats.improved,
                        i,
                        g,
                    );
                } else if !queued[i] {
                    // went stale under an earlier apply of this batch:
                    // back into the queue for the next round
                    queued[i] = true;
                    queue.push(id, g);
                }
            }
        }
    }

    // deterministic speculative prefetch (threads > 1, default mode):
    // between pops, peek the next entries in exact pop order and
    // pre-evaluate the stale ones on worker threads into the side
    // cache. Queue placement and the authoritative gain/stamp arrays
    // are untouched, so the pop sequence below is exactly the
    // sequential one; a side-cache hit substitutes for (and is counted
    // as) the one evaluation the sequential drain would perform.
    let par = threads > 1 && !free;
    let (spec_batch, spec_window) = if par {
        spec_gain.clear();
        spec_gain.resize(nm, 0);
        spec_stamp.clear();
        spec_stamp.resize(nm, [0; 3]);
        spec_valid.clear();
        spec_valid.resize(nm, false);
        (threads * SPEC_BATCH_PER_THREAD, threads * SPEC_WINDOW_PER_THREAD)
    } else {
        (0, 0)
    };
    let mut spec_ids: Vec<u32> = Vec::with_capacity(spec_batch);
    let mut spec_out: Vec<(i64, [u64; 3])> = Vec::with_capacity(spec_batch);
    let mut until_respec = 0usize;
    // drain ticks for the control check: fresh pops apply without an
    // evaluation, so `stats.evaluated` alone can stall between checks
    let mut ticks = 0u64;

    loop {
        if par && until_respec == 0 && !queue.is_empty() {
            // speculation round: pre-evaluate the stale upcoming pops
            queue.peek_upcoming(spec_batch, &mut spec_ids);
            spec_ids.retain(|&id| {
                let i = id as usize;
                let now = stamp_of(&*engine, versioned, stats.improved, pairs, tri_list, np, i);
                stamp[i] != now && !(spec_valid[i] && spec_stamp[i] == now)
            });
            if spec_ids.len() >= 2 {
                spec_out.clear();
                spec_out.resize(spec_ids.len(), (0, [0; 3]));
                let chunk = spec_ids.len().div_ceil(threads);
                let eng: &dyn Swapper = &*engine;
                let epoch = stats.improved;
                std::thread::scope(|s| {
                    for (ids, out) in spec_ids.chunks(chunk).zip(spec_out.chunks_mut(chunk)) {
                        s.spawn(move || {
                            for (&id, slot) in ids.iter().zip(out.iter_mut()) {
                                *slot = evaluate(
                                    eng,
                                    versioned,
                                    epoch,
                                    pairs,
                                    tri_list,
                                    np,
                                    id as usize,
                                );
                            }
                        });
                    }
                });
                for (&id, &(g, st)) in spec_ids.iter().zip(&spec_out) {
                    let i = id as usize;
                    spec_gain[i] = g;
                    spec_stamp[i] = st;
                    spec_valid[i] = true;
                }
            }
            until_respec = spec_window;
        }
        let Some(i) = queue.pop() else { break };
        ticks += 1;
        if armed && ticks % control::CHECK_EVERY == 0 {
            if let Some(r) = ctrl.stop_reason() {
                stats.stopped = Some(r);
                break;
            }
        }
        until_respec = until_respec.saturating_sub(1);
        let i = i as usize;
        queued[i] = false;
        let now = stamp_of(&*engine, versioned, stats.improved, pairs, tri_list, np, i);
        let fresh = stamp[i] == now;
        let g = if fresh {
            gain[i]
        } else {
            // one evaluation, exactly where the sequential drain pays
            // it — served from the speculative side cache when its
            // stamp still matches (same state ⇒ same gain)
            let (g, st) = if par && spec_valid[i] && spec_stamp[i] == now {
                (spec_gain[i], now)
            } else {
                evaluate(&*engine, versioned, stats.improved, pairs, tri_list, np, i)
            };
            stats.evaluated += 1;
            gain[i] = g;
            stamp[i] = st;
            g
        };
        if g <= 0 {
            continue;
        }
        if !fresh {
            // freshly re-evaluated and still improving: back into the
            // queue at its true priority instead of applying out of
            // order (it is popped right back when it is still the best)
            queued[i] = true;
            queue.push(i as u32, g);
            continue;
        }
        // fresh and improving: the cached gain is exact — apply without
        // paying a second evaluation (the dense engine's overrides skip
        // the O(n) row scan its do_swap/do_rotate3 would burn
        // recomputing g)
        apply_and_activate(
            &mut *engine,
            comm,
            pairs,
            tris,
            tri_list,
            np,
            queue,
            queued,
            gain,
            stamp,
            versioned,
            &mut stats.improved,
            i,
            g,
        );
    }
}

/// The gain-cached refiner over the unified move class: `gc:nc<d>`
/// (pair swaps only, [`Self::new`]) and `gc:nccyc<d>` (pair swaps *and*
/// 3-cycle triangle rotations in one queue, [`Self::with_rotations`]) in
/// the spec grammar.
///
/// Owns the pair and triangle sets + incidence indexes (rebuilt only when
/// the refined graph or `d` changes, like every refiner's scratch) and the
/// per-run queue, gain, stamp and queued-flag arrays (resized and refilled
/// each call, so repetitions and V-cycle levels reuse the allocations).
#[derive(Debug, Clone, Default)]
pub struct GainCacheNc {
    /// Maximum communication-graph distance of a swappable pair (public
    /// knob, mirroring [`super::NcNeighborhood::d`]).
    pub d: u32,
    /// Queue triangle rotations alongside the pair swaps (`gc:nccyc<d>`).
    /// Engines without rotation support degrade to the pair-only queue.
    rotations: bool,
    pairs: Option<PairIndex>,
    /// Shared canonical triangle enumeration (the [`super::Cycle3`] cache
    /// type, so both refiners search the identical set).
    tri_set: TriangleSet,
    tris: Option<TriIndex>,
    queue: GainBucketQueue,
    /// Last evaluated gain per move (exact while the stamp is fresh; a
    /// search-order hint otherwise).
    gain: Vec<i64>,
    /// Endpoint versions at the last evaluation (all components equal the
    /// refiner's applied-move epoch for unversioned engines; pair moves
    /// leave the third slot 0).
    stamp: Vec<[u64; 3]>,
    /// Whether the move currently has a queue entry (dedups re-activation).
    queued: Vec<bool>,
    /// Worker threads for the parallel seeding sweep and the parallel
    /// drain; `0`/`1` selects the classic sequential path. Set via
    /// [`Self::threads`].
    threads: usize,
    /// Free-running parallel drain ([`Self::free_running`]): rounds of
    /// batched parallel evaluation with stamp-revalidated applies. The
    /// move trajectory may diverge from the sequential one, but the final
    /// sequential drain still certifies the union-neighborhood local
    /// optimum. Off by default — the default parallel drain is the
    /// deterministic speculative one, bit-identical to `threads == 1`.
    free: bool,
    /// Speculative side cache of the deterministic parallel drain:
    /// per-move (gain, stamp-at-evaluation), consumed at pop time only
    /// when the stamp still matches the live state — then the cached gain
    /// equals what evaluating at the pop would return, so the trajectory
    /// and the `evaluated` count stay exactly sequential.
    spec_gain: Vec<i64>,
    spec_stamp: Vec<[u64; 3]>,
    spec_valid: Vec<bool>,
    /// True when the last [`Refiner::refine`] / [`Self::refine_warm`] call
    /// ran its drain to completion (empty queue, no stop): at that point
    /// the persisted gain/stamp/queued arrays describe a certified local
    /// optimum — every stamp fresh, every gain exact and `≤ 0` — which is
    /// the state [`Self::refine_warm`] is allowed to resume from. Any
    /// early-stopped or partial run clears it.
    quiescent: bool,
    /// Anytime stop token ([`Refiner::set_control`]); disarmed by default.
    ctrl: RunControl,
}

impl GainCacheNc {
    /// Pair-swap-only queue (`gc:nc<d>`).
    pub fn new(d: u32) -> GainCacheNc {
        GainCacheNc { d, ..GainCacheNc::default() }
    }

    /// Unified move-class queue (`gc:nccyc<d>`): the `N_C^d` pairs plus
    /// both rotation directions of every communication-graph triangle.
    pub fn with_rotations(d: u32) -> GainCacheNc {
        GainCacheNc { d, rotations: true, ..GainCacheNc::default() }
    }

    /// Set the worker-thread count (builder style). `0` and `1` both run
    /// the classic sequential path; any larger `t` parallelizes the
    /// seeding sweep and the drain across `t` scoped threads. The default
    /// deterministic mode is bit-identical to the sequential refiner —
    /// same moves, same mapping, same [`SearchStats`] — at every `t`.
    pub fn threads(mut self, t: usize) -> GainCacheNc {
        self.threads = t;
        self
    }

    /// Opt into the free-running parallel drain (builder style): batches
    /// of candidates are evaluated concurrently and applied with per-move
    /// stamp revalidation, trading the bit-identical trajectory for less
    /// synchronization. Termination still certifies the same
    /// union-neighborhood local-optimum class (a final sequential drain
    /// runs to quiescence). No effect at `threads <= 1`.
    pub fn free_running(mut self, yes: bool) -> GainCacheNc {
        self.free = yes;
        self
    }

    fn ensure_index(&mut self, comm: &Graph, rot: bool) {
        let key = graph_key(comm);
        let stale = match &self.pairs {
            Some(idx) => idx.key != key || idx.d != self.d,
            None => true,
        };
        if stale {
            self.pairs = Some(PairIndex::build(comm, self.d, key));
        }
        if rot {
            let stale = match &self.tris {
                Some(t) => t.key != key,
                None => true,
            };
            if stale {
                let list = self.tri_set.get(comm);
                let idx = TriIndex::build(comm.n(), list, key);
                self.tris = Some(idx);
            }
        }
    }
}

impl Refiner for GainCacheNc {
    fn name(&self) -> String {
        if self.rotations {
            format!("GcNcCyc{}", self.d)
        } else {
            format!("GcNc{}", self.d)
        }
    }

    fn set_control(&mut self, ctrl: &RunControl) {
        self.ctrl = ctrl.clone();
    }

    /// Statistics: `evaluated` counts gain computations (one seeding sweep
    /// over every move plus the lazy re-evaluations of stale pops),
    /// `improved` the applied moves (a rotation counts once), `rounds` the
    /// single seeding sweep. The RNG is never consulted.
    ///
    /// With [`Self::threads`] `> 1` the seeding sweep is chunked across
    /// scoped worker threads (read-only on the engine, disjoint `&mut`
    /// chunks of the gain/stamp arrays) and the drain pre-evaluates
    /// upcoming stale pops speculatively on the same workers. In the
    /// default deterministic mode the pop/apply sequence — and therefore
    /// the final mapping *and* these statistics — is bit-identical to the
    /// sequential refiner at every thread count; speculative evaluations
    /// are only consumed at pop time when their stamp still matches (then
    /// they equal what the sequential evaluation would return) and wasted
    /// speculation is never counted. [`Self::free_running`] trades that
    /// bit-identity for round-based parallel applies, then certifies the
    /// same union-neighborhood local-optimum class with a final
    /// sequential drain.
    fn refine(&mut self, engine: &mut dyn Swapper, comm: &Graph, _rng: &mut Rng) -> SearchStats {
        let rot = self.rotations && engine.supports_rotate3();
        // cleared up front so an early-stopped run can never leave a stale
        // quiescence claim for refine_warm to resume from
        self.quiescent = false;
        self.ensure_index(comm, rot);
        // the triangle coordinates live once, in the shared TriangleSet
        // cache (warm after ensure_index); the TriIndex holds only the CSR
        let tri_list: &[(NodeId, NodeId, NodeId)] =
            if rot { self.tri_set.get(comm) } else { &[] };
        let pairs = self.pairs.as_ref().expect("ensure_index filled the pair cache");
        let tris = if rot { self.tris.as_ref() } else { None };
        let np = pairs.pairs.len();
        let nm = np + 2 * tri_list.len();
        let mut stats = SearchStats::default();
        if nm == 0 {
            return stats;
        }
        let versioned = engine.supports_versions();
        let threads = self.threads.max(1).min(nm);
        let armed = self.ctrl.armed();

        // seed: evaluate every move once, queue the improving ones. The
        // sweep is read-only on the engine, so at threads > 1 it is
        // chunked across scoped workers writing disjoint gain/stamp
        // slices; queue pushes then happen in fixed id order on this
        // thread, so the bucket layout (LIFO within a bucket) is the
        // sequential one at every thread count.
        self.queue.clear();
        self.gain.clear();
        self.gain.resize(nm, 0);
        self.stamp.clear();
        self.stamp.resize(nm, [0; 3]);
        self.queued.clear();
        self.queued.resize(nm, false);
        if threads > 1 {
            let chunk = nm.div_ceil(threads);
            let eng: &dyn Swapper = &*engine;
            std::thread::scope(|s| {
                for (ci, (gs, ss)) in self
                    .gain
                    .chunks_mut(chunk)
                    .zip(self.stamp.chunks_mut(chunk))
                    .enumerate()
                {
                    let base = ci * chunk;
                    s.spawn(move || {
                        for (k, (g_out, st_out)) in
                            gs.iter_mut().zip(ss.iter_mut()).enumerate()
                        {
                            let (g, st) =
                                evaluate(eng, versioned, 0, pairs, tri_list, np, base + k);
                            *g_out = g;
                            *st_out = st;
                        }
                    });
                }
            });
            stats.evaluated += nm as u64;
            for i in 0..nm {
                if self.gain[i] > 0 {
                    self.queued[i] = true;
                    self.queue.push(i as u32, self.gain[i]);
                }
            }
            // one check per parallel sweep: no move has been applied yet,
            // so stopping here returns the start mapping untouched
            if armed {
                if let Some(r) = self.ctrl.stop_reason() {
                    stats.stopped = Some(r);
                    stats.rounds = 1;
                    return stats;
                }
            }
        } else {
            for i in 0..nm {
                let (g, st) =
                    evaluate(&*engine, versioned, stats.improved, pairs, tri_list, np, i);
                stats.evaluated += 1;
                self.gain[i] = g;
                self.stamp[i] = st;
                if g > 0 {
                    self.queued[i] = true;
                    self.queue.push(i as u32, g);
                }
                if armed && stats.evaluated % control::CHECK_EVERY == 0 {
                    if let Some(r) = self.ctrl.stop_reason() {
                        stats.stopped = Some(r);
                        stats.rounds = 1;
                        return stats;
                    }
                }
            }
        }
        stats.rounds = 1;

        drain(
            &mut *engine,
            comm,
            pairs,
            tris,
            tri_list,
            np,
            versioned,
            threads,
            self.free,
            &self.ctrl,
            &mut self.queue,
            &mut self.gain,
            &mut self.stamp,
            &mut self.queued,
            &mut self.spec_gain,
            &mut self.spec_stamp,
            &mut self.spec_valid,
            &mut stats,
        );
        self.quiescent = stats.stopped.is_none();
        stats
    }

    /// The REMAP warm resume: re-seed only the moves incident to `touched`
    /// and drain from there, instead of the full `O(|moves|)` seeding sweep.
    ///
    /// Preconditions (any failure returns `None`, telling the caller to
    /// fall back to a full [`Refiner::refine`]):
    /// * the previous call on this refiner drained to quiescence
    ///   ([`Self::quiescent`]) — its persisted gains are exact and `≤ 0`,
    /// * the cached pair index matches the current `d` and `comm`'s vertex
    ///   and edge counts, and
    /// * `comm` is the *same graph, weight-patched only* — the caller's
    ///   contract ([`crate::api::MapSession::remap`] only takes this path
    ///   for weight-only delta batches on the session's own graph), since
    ///   structural inserts shift the packed move-id space.
    ///
    /// Under that contract the cached pair/triangle sets are structurally
    /// current, so they are re-keyed in place ([`TriangleSet::retag`])
    /// rather than re-enumerated. `touched` lists the vertices whose
    /// incident edge weights changed (deduplication not required): exactly
    /// the moves incident to one of them can have gone stale or improving
    /// — every other move's gain is unchanged and `≤ 0` — so re-stamping
    /// and re-pushing those ids in ascending order rebuilds precisely the
    /// queue a cold full seed on the patched graph would build, and the
    /// drain trajectory (moves, final σ, final J) is bit-identical to the
    /// cold path from the same start mapping. Only `evaluated` differs:
    /// `O(|touched| · deg)` instead of `O(|moves|)`.
    fn refine_warm(
        &mut self,
        engine: &mut dyn Swapper,
        comm: &Graph,
        touched: &[NodeId],
    ) -> Option<SearchStats> {
        let rot = self.rotations && engine.supports_rotate3();
        if !self.quiescent || !self.queue.is_empty() {
            return None;
        }
        self.quiescent = false;
        {
            let idx = self.pairs.as_ref()?;
            if idx.d != self.d || idx.key.0 != comm.n() || idx.key.1 != comm.m() {
                return None;
            }
            if rot && self.tris.is_none() {
                return None;
            }
        }
        // weight-only deltas changed the graph key but not the structure
        // (the caller's contract): re-tag every cached index in place
        let key = graph_key(comm);
        self.pairs.as_mut().expect("checked above").key = key;
        if rot {
            self.tris.as_mut().expect("checked above").key = key;
            if !self.tri_set.retag(comm) {
                return None;
            }
        }
        let tri_list: &[(NodeId, NodeId, NodeId)] =
            if rot { self.tri_set.get(comm) } else { &[] };
        let pairs = self.pairs.as_ref().expect("checked above");
        let tris = if rot { self.tris.as_ref() } else { None };
        let np = pairs.pairs.len();
        let nm = np + 2 * tri_list.len();
        if self.gain.len() != nm || self.stamp.len() != nm || self.queued.len() != nm {
            return None;
        }
        let mut stats = SearchStats::default();
        if nm == 0 {
            self.quiescent = true;
            return Some(stats);
        }
        let versioned = engine.supports_versions();
        let threads = self.threads.max(1).min(nm);
        let armed = self.ctrl.armed();

        // partial re-seed: the incidence indexes answer "which moves did
        // this edge touch" — collect them in ascending id order (matching
        // the cold full seed's push order, hence the same bucket layout)
        let mut ids: Vec<u32> = Vec::new();
        for &x in touched {
            if x as usize >= comm.n() {
                return None;
            }
            ids.extend_from_slice(pairs.incident(x));
            if let Some(ti) = tris {
                for &t in ti.incident(x) {
                    let base = (np + 2 * t as usize) as u32;
                    ids.push(base);
                    ids.push(base + 1);
                }
            }
        }
        ids.sort_unstable();
        ids.dedup();
        for &id in &ids {
            let i = id as usize;
            let (g, st) = evaluate(&*engine, versioned, stats.improved, pairs, tri_list, np, i);
            stats.evaluated += 1;
            self.gain[i] = g;
            self.stamp[i] = st;
            if g > 0 {
                self.queued[i] = true;
                self.queue.push(id, g);
            }
            if armed && stats.evaluated % control::CHECK_EVERY == 0 {
                if let Some(r) = self.ctrl.stop_reason() {
                    stats.stopped = Some(r);
                    stats.rounds = 1;
                    return Some(stats);
                }
            }
        }
        stats.rounds = 1;

        drain(
            &mut *engine,
            comm,
            pairs,
            tris,
            tri_list,
            np,
            versioned,
            threads,
            self.free,
            &self.ctrl,
            &mut self.queue,
            &mut self.gain,
            &mut self.stamp,
            &mut self.queued,
            &mut self.spec_gain,
            &mut self.spec_stamp,
            &mut self.spec_valid,
            &mut stats,
        );
        self.quiescent = stats.stopped.is_none();
        Some(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::objective::{DenseEngine, Mapping, SwapEngine};
    use crate::mapping::refine::{comm_triangles, Cycle3, NcNeighborhood};
    use crate::model::topology::{Hierarchy, Machine};

    fn setup(nexp: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << nexp) / 64], vec![1, 10, 100]).unwrap();
        (g, Machine::implicit(h))
    }

    #[test]
    fn bucket_queue_pops_max_first() {
        let mut q = GainBucketQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        q.push(1, 5);
        q.push(2, 100);
        q.push(3, 1);
        q.push(4, 100); // same bucket: LIFO
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(2));
        q.push(5, 7); // push above the current top after it decayed
        assert_eq!(q.pop(), Some(5));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn bucket_queue_clamps_extremes_into_end_buckets() {
        let mut q = GainBucketQueue::new();
        q.push(1, -50); // bucket 0
        q.push(2, i64::MAX); // top bucket
        q.push(3, 2);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(1));
        q.clear();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn gaincache_true_local_optimum_and_not_worse_than_shuffle() {
        // the two halves of the pair-only quality claim: the queue drains
        // exactly at a provable local optimum of N_C^d, and at an equal
        // evaluation budget (the fair framing of "fewer evaluations, no
        // worse J" — the unbudgeted comparison is ablation_ls's job) the
        // final objective is no worse than the shuffle search from the same
        // starts
        let (g, o) = setup(7, 80);
        let d = 2;
        let mut gc = GainCacheNc::new(d);
        let (mut prod_gc, mut prod_shuffle) = (1.0f64, 1.0f64);
        for s in 0..3u64 {
            let m = {
                let mut r = Rng::new(81 + s);
                Mapping { sigma: r.permutation(g.n()) }
            };
            let mut e1 = SwapEngine::new(&g, &o, m.clone());
            let mut r1 = Rng::new(1);
            let stats = gc.refine(&mut e1, &g, &mut r1);
            assert!(stats.improved > 0, "random start must improve");
            assert!(stats.evaluated >= nc_pairs(&g, d).len() as u64);
            for &(a, b) in &nc_pairs(&g, d) {
                assert!(
                    e1.swap_gain(a, b) <= 0,
                    "improving pair ({a},{b}) left behind at the claimed optimum"
                );
            }
            e1.mapping().validate().unwrap();
            assert_eq!(e1.objective(), e1.recompute_objective());

            let mut e2 = SwapEngine::new(&g, &o, m);
            let mut r2 = Rng::new(83 + s);
            NcNeighborhood::with_budget(d, stats.evaluated).refine(&mut e2, &g, &mut r2);
            prod_gc *= e1.objective() as f64;
            prod_shuffle *= e2.objective() as f64;
        }
        assert!(
            prod_gc <= prod_shuffle,
            "gain cache ended worse than the equal-budget shuffle search: \
             {prod_gc} vs {prod_shuffle}"
        );
    }

    #[test]
    fn unified_queue_reaches_union_neighborhood_local_optimum() {
        // the tentpole acceptance criterion: at the drained queue an
        // exhaustive scan finds no improving N_C^d pair AND no improving
        // rotation in either direction of any communication triangle — the
        // provable local optimum of the union move class
        let (g, o) = setup(7, 94);
        let d = 2;
        let mut gc = GainCacheNc::with_rotations(d);
        let m = {
            let mut r = Rng::new(95);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut eng = SwapEngine::new(&g, &o, m);
        let stats = gc.refine(&mut eng, &g, &mut Rng::new(1));
        assert!(stats.improved > 0, "random start must improve");
        let tris = comm_triangles(&g);
        assert!(!tris.is_empty(), "rgg comm graphs contain triangles");
        assert!(stats.evaluated >= (nc_pairs(&g, d).len() + 2 * tris.len()) as u64);
        for &(a, b) in &nc_pairs(&g, d) {
            assert!(
                eng.swap_gain(a, b) <= 0,
                "improving pair ({a},{b}) left behind at the claimed union optimum"
            );
        }
        for &(a, b, c) in &tris {
            assert!(
                eng.rotate3_gain(a, b, c) <= 0,
                "improving rotation ({a},{b},{c}) left behind"
            );
            assert!(
                eng.rotate3_gain(a, c, b) <= 0,
                "improving reverse rotation ({a},{c},{b}) left behind"
            );
        }
        eng.mapping().validate().unwrap();
        assert_eq!(eng.objective(), eng.recompute_objective());
        assert_eq!(stats.improved, eng.swaps_applied, "a rotation counts as one move");
    }

    #[test]
    fn unified_queue_not_worse_than_phased_nccycle_at_equal_budget() {
        // the equal-budget quality claim for the union move class: give the
        // phased NcCyc<d> baseline the unified queue's whole evaluation
        // budget in its pair phase and let its rotation phase run free (so
        // it spends at least as many evaluations), starting from identical
        // mappings — the unified queue's final J is never worse over the
        // seed set
        let (g, o) = setup(7, 96);
        let d = 2;
        let mut gc = GainCacheNc::with_rotations(d);
        let (mut prod_u, mut prod_p) = (1.0f64, 1.0f64);
        for s in 0..3u64 {
            let m = {
                let mut r = Rng::new(97 + s);
                Mapping { sigma: r.permutation(g.n()) }
            };
            let mut e1 = SwapEngine::new(&g, &o, m.clone());
            let stats = gc.refine(&mut e1, &g, &mut Rng::new(1));
            let mut e2 = SwapEngine::new(&g, &o, m);
            let mut r2 = Rng::new(99 + s);
            NcNeighborhood::with_budget(d, stats.evaluated).refine(&mut e2, &g, &mut r2);
            Cycle3::new(100).refine(&mut e2, &g, &mut r2);
            prod_u *= e1.objective() as f64;
            prod_p *= e2.objective() as f64;
        }
        assert!(
            prod_u <= prod_p,
            "unified queue ended worse than the equal-budget phased NcCyc: \
             {prod_u} vs {prod_p}"
        );
    }

    #[test]
    fn gaincache_is_deterministic_and_rng_independent() {
        // no shuffle anywhere: the trajectory is a pure function of the
        // start mapping, whatever RNG state the caller threads through —
        // for the pair-only queue AND the unified move class
        let (g, o) = setup(7, 84);
        let m = {
            let mut r = Rng::new(85);
            Mapping { sigma: r.permutation(g.n()) }
        };
        for rot in [false, true] {
            let mk = |d| if rot { GainCacheNc::with_rotations(d) } else { GainCacheNc::new(d) };
            let mut e1 = SwapEngine::new(&g, &o, m.clone());
            let s1 = mk(2).refine(&mut e1, &g, &mut Rng::new(1));
            let mut e2 = SwapEngine::new(&g, &o, m.clone());
            let s2 = mk(2).refine(&mut e2, &g, &mut Rng::new(999));
            assert_eq!(e1.mapping(), e2.mapping(), "rotations={rot}");
            assert_eq!(e1.objective(), e2.objective(), "rotations={rot}");
            assert_eq!(s1, s2, "rotations={rot}");
        }
    }

    #[test]
    fn dense_and_sparse_follow_identical_trajectory_under_gaincache() {
        // the epoch fallback must not change the move sequence: an
        // epoch-stale re-evaluation of an untouched pair returns its cached
        // gain, so the dense engine re-pops it from the same bucket and
        // applies the same swap — only `evaluated` differs
        let (g, o) = setup(6, 86);
        let m = {
            let mut r = Rng::new(87);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut fast = SwapEngine::new(&g, &o, m.clone());
        let mut slow = DenseEngine::new(&g, &o, m);
        let sf = GainCacheNc::new(2).refine(&mut fast, &g, &mut Rng::new(1));
        let ss = GainCacheNc::new(2).refine(&mut slow, &g, &mut Rng::new(1));
        assert_eq!(fast.mapping(), slow.mapping());
        assert_eq!(fast.objective(), slow.objective());
        assert_eq!(sf.improved, ss.improved);
        assert!(
            ss.evaluated >= sf.evaluated,
            "the unversioned fallback cannot evaluate less than per-vertex stamping"
        );
    }

    #[test]
    fn dense_and_sparse_follow_identical_trajectory_with_queued_rotations() {
        // the same bit-identical-trajectory contract for the unified move
        // class: queued rotations must pop and apply in the same order
        // under per-vertex stamping and under the epoch fallback
        let (g, o) = setup(6, 88);
        let m = {
            let mut r = Rng::new(89);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut fast = SwapEngine::new(&g, &o, m.clone());
        let mut slow = DenseEngine::new(&g, &o, m);
        let sf = GainCacheNc::with_rotations(2).refine(&mut fast, &g, &mut Rng::new(1));
        let ss = GainCacheNc::with_rotations(2).refine(&mut slow, &g, &mut Rng::new(1));
        assert_eq!(fast.mapping(), slow.mapping());
        assert_eq!(fast.objective(), slow.objective());
        assert_eq!(sf.improved, ss.improved);
        assert!(
            ss.evaluated >= sf.evaluated,
            "the unversioned fallback cannot evaluate less than per-vertex stamping"
        );
        assert_eq!(slow.objective(), slow.recompute_objective());
    }

    #[test]
    fn rotationless_engine_degrades_to_the_pair_only_queue() {
        // an engine without rotation support under gc:nccyc<d> follows
        // exactly the gc:nc<d> trajectory (zero rotation evaluations)
        struct NoRot<'a>(SwapEngine<'a>);
        impl Swapper for NoRot<'_> {
            fn swap_gain(&self, u: NodeId, v: NodeId) -> i64 {
                self.0.swap_gain(u, v)
            }
            fn do_swap(&mut self, u: NodeId, v: NodeId) {
                self.0.do_swap(u, v)
            }
            fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
                self.0.try_swap(u, v)
            }
            fn objective(&self) -> u64 {
                self.0.objective()
            }
            fn pe_of(&self, u: NodeId) -> u32 {
                self.0.pe_of(u)
            }
            fn version_of(&self, u: NodeId) -> u64 {
                self.0.version_of(u)
            }
            fn supports_versions(&self) -> bool {
                true
            }
            // rotation hooks stay default-unsupported
        }
        let (g, o) = setup(6, 90);
        let m = {
            let mut r = Rng::new(91);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut norot = NoRot(SwapEngine::new(&g, &o, m.clone()));
        let s1 = GainCacheNc::with_rotations(2).refine(&mut norot, &g, &mut Rng::new(1));
        let mut plain = SwapEngine::new(&g, &o, m);
        let s2 = GainCacheNc::new(2).refine(&mut plain, &g, &mut Rng::new(1));
        assert_eq!(norot.0.mapping(), plain.mapping());
        assert_eq!(norot.0.objective(), plain.objective());
        assert_eq!(s1, s2);
    }

    #[test]
    fn epoch_stamps_do_not_alias_past_u32() {
        // the unversioned fallback stamps the refiner's full u64
        // applied-move epoch; the former `(epoch as u32, epoch as u32)`
        // truncation aliased epoch 2^32 with epoch 0, which would have let
        // a move stamped 2^32 applied moves earlier pass the freshness
        // check and apply its stale cached gain blind
        struct NoVersions;
        impl Swapper for NoVersions {
            fn swap_gain(&self, _u: NodeId, _v: NodeId) -> i64 {
                0
            }
            fn do_swap(&mut self, _u: NodeId, _v: NodeId) {}
            fn try_swap(&mut self, _u: NodeId, _v: NodeId) -> Option<i64> {
                None
            }
            fn objective(&self) -> u64 {
                0
            }
            fn pe_of(&self, u: NodeId) -> u32 {
                u
            }
        }
        let g = crate::graph::from_edges(3, &[(0, 1, 1), (1, 2, 1), (0, 2, 1)]);
        let idx = PairIndex::build(&g, 1, graph_key(&g));
        let np = idx.pairs.len();
        let eng = NoVersions;
        let s0 = stamp_of(&eng, false, 0, &idx, &[], np, 0);
        let s32 = stamp_of(&eng, false, 1u64 << 32, &idx, &[], np, 0);
        assert_eq!(s0, [0u64; 3]);
        assert_eq!(s32, [1u64 << 32; 3]);
        assert_ne!(s0, s32, "u64 epochs must not alias mod 2^32");
    }

    #[test]
    fn kept_alive_gaincache_matches_fresh() {
        // the scratch-reuse contract every refiner honors: reusing the
        // cached pair/triangle/incidence indexes replays a fresh refiner
        // exactly — for both move classes
        let (g, o) = setup(7, 88);
        let m = {
            let mut r = Rng::new(89);
            Mapping { sigma: r.permutation(g.n()) }
        };
        for rot in [false, true] {
            let mk = |d| if rot { GainCacheNc::with_rotations(d) } else { GainCacheNc::new(d) };
            let mut refiner = mk(2);
            {
                let mut warm = SwapEngine::new(&g, &o, m.clone());
                refiner.refine(&mut warm, &g, &mut Rng::new(1));
            }
            let mut e1 = SwapEngine::new(&g, &o, m.clone());
            let s1 = refiner.refine(&mut e1, &g, &mut Rng::new(1));
            let mut e2 = SwapEngine::new(&g, &o, m.clone());
            let s2 = mk(2).refine(&mut e2, &g, &mut Rng::new(1));
            assert_eq!(e1.mapping(), e2.mapping(), "rotations={rot}");
            assert_eq!(s1, s2, "rotations={rot}");
        }
    }

    #[test]
    fn changing_d_invalidates_the_pair_index() {
        let (g, o) = setup(7, 90);
        let m = {
            let mut r = Rng::new(91);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut refiner = GainCacheNc::new(1);
        {
            let mut warm = SwapEngine::new(&g, &o, m.clone());
            refiner.refine(&mut warm, &g, &mut Rng::new(1));
        }
        refiner.d = 2;
        let mut e1 = SwapEngine::new(&g, &o, m.clone());
        let s1 = refiner.refine(&mut e1, &g, &mut Rng::new(1));
        let mut e2 = SwapEngine::new(&g, &o, m);
        let s2 = GainCacheNc::new(2).refine(&mut e2, &g, &mut Rng::new(1));
        assert_eq!(e1.mapping(), e2.mapping());
        assert_eq!(s1, s2);
    }

    #[test]
    fn empty_pair_set_is_a_noop() {
        let g = crate::graph::from_edges(4, &[]);
        let h = Hierarchy::new(vec![4], vec![1]).unwrap();
        let o = Machine::implicit(h);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(4));
        let stats = GainCacheNc::new(1).refine(&mut eng, &g, &mut Rng::new(1));
        assert_eq!(stats, SearchStats::default());
        // the unified class on an edgeless graph has no triangles either
        let stats = GainCacheNc::with_rotations(1).refine(&mut eng, &g, &mut Rng::new(1));
        assert_eq!(stats, SearchStats::default());
        assert_eq!(eng.objective(), 0);
    }

    #[test]
    fn peek_upcoming_matches_pop_order_and_removes_nothing() {
        let mut q = GainBucketQueue::new();
        let mut out = vec![7u32]; // stale content must be cleared
        q.peek_upcoming(4, &mut out);
        assert!(out.is_empty());
        q.push(1, 5);
        q.push(2, 100);
        q.push(3, 1);
        q.push(4, 100); // same bucket as 2: LIFO puts it first
        q.peek_upcoming(3, &mut out);
        assert_eq!(out, vec![4, 2, 1]);
        q.peek_upcoming(10, &mut out);
        assert_eq!(out, vec![4, 2, 1, 3]);
        assert_eq!(q.len(), 4, "peeking removes nothing");
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn parallel_deterministic_mode_is_bit_identical_at_any_thread_count() {
        // tentpole acceptance: parallel seeding + the speculative drain
        // replay the sequential trajectory exactly — mapping, objective,
        // and the full SearchStats — at T ∈ {2, 4}, for both move classes
        let (g, o) = setup(7, 120);
        let m = {
            let mut r = Rng::new(121);
            Mapping { sigma: r.permutation(g.n()) }
        };
        for rot in [false, true] {
            let mk = |d| if rot { GainCacheNc::with_rotations(d) } else { GainCacheNc::new(d) };
            let mut base = SwapEngine::new(&g, &o, m.clone());
            let s1 = mk(2).refine(&mut base, &g, &mut Rng::new(1));
            assert!(s1.improved > 0, "random start must improve");
            for t in [2usize, 4] {
                let mut eng = SwapEngine::new(&g, &o, m.clone());
                let st = mk(2).threads(t).refine(&mut eng, &g, &mut Rng::new(1));
                assert_eq!(eng.mapping(), base.mapping(), "rotations={rot} threads={t}");
                assert_eq!(eng.objective(), base.objective(), "rotations={rot} threads={t}");
                assert_eq!(st, s1, "stats must replay exactly: rotations={rot} threads={t}");
            }
        }
    }

    #[test]
    fn parallel_deterministic_mode_matches_under_the_epoch_fallback() {
        // the unversioned dense baseline takes the same parallel paths
        // (its stamps are the refiner's own epoch) and must still replay
        // the sequential trajectory bit-for-bit
        let (g, o) = setup(6, 124);
        let m = {
            let mut r = Rng::new(125);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut base = DenseEngine::new(&g, &o, m.clone());
        let s1 = GainCacheNc::with_rotations(2).refine(&mut base, &g, &mut Rng::new(1));
        let mut par = DenseEngine::new(&g, &o, m);
        let s4 = GainCacheNc::with_rotations(2).threads(4).refine(&mut par, &g, &mut Rng::new(1));
        assert_eq!(par.mapping(), base.mapping());
        assert_eq!(par.objective(), base.objective());
        assert_eq!(s4, s1);
    }

    #[test]
    fn free_running_mode_reaches_a_union_neighborhood_local_optimum() {
        // free-running applies may reorder (the trajectory is allowed to
        // diverge from sequential) but the terminal state must satisfy the
        // same certificate: no improving pair and no improving rotation in
        // either direction, on a consistent engine
        let (g, o) = setup(7, 126);
        let d = 2;
        let m = {
            let mut r = Rng::new(127);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut eng = SwapEngine::new(&g, &o, m);
        let stats = GainCacheNc::with_rotations(d)
            .threads(4)
            .free_running(true)
            .refine(&mut eng, &g, &mut Rng::new(1));
        assert!(stats.improved > 0, "random start must improve");
        for &(a, b) in &nc_pairs(&g, d) {
            assert!(eng.swap_gain(a, b) <= 0, "improving pair ({a},{b}) left behind");
        }
        for &(a, b, c) in &comm_triangles(&g) {
            assert!(eng.rotate3_gain(a, b, c) <= 0, "improving rotation left behind");
            assert!(eng.rotate3_gain(a, c, b) <= 0, "improving reverse rotation left behind");
        }
        eng.mapping().validate().unwrap();
        assert_eq!(eng.objective(), eng.recompute_objective());
        assert_eq!(stats.improved, eng.swaps_applied);
    }

    #[test]
    fn refine_warm_matches_cold_rebuild_bit_for_bit() {
        // the REMAP correctness contract at the refiner level: drain to
        // quiescence, weight-patch the graph, resume warm — the final
        // mapping and objective must be bit-identical to a cold full-seed
        // refine on the patched graph from the same σ, for both move
        // classes and at T ∈ {1, 2, 4}, while evaluating strictly less
        use crate::graph::EdgeDelta;
        let (g, o) = setup(7, 140);
        let m = {
            let mut r = Rng::new(141);
            Mapping { sigma: r.permutation(g.n()) }
        };
        for rot in [false, true] {
            let mk = |d| if rot { GainCacheNc::with_rotations(d) } else { GainCacheNc::new(d) };
            for t in [1usize, 2, 4] {
                let mut refiner = mk(2).threads(t);
                let mut eng = SwapEngine::new(&g, &o, m.clone());
                refiner.refine(&mut eng, &g, &mut Rng::new(1));
                let parts = eng.into_warm_parts();
                let sigma_opt = parts.mapping.clone();

                // weight-only drift on a handful of existing edges
                let e1 = (0 as NodeId, g.neighbors(0)[0]);
                let e2 = (5 as NodeId, g.neighbors(5)[0]);
                let mut g2 = g.clone();
                let out = g2
                    .apply_deltas(&[
                        EdgeDelta { u: e1.0, v: e1.1, w: g.edge_weight(e1.0, e1.1).unwrap() + 11 },
                        EdgeDelta { u: e2.0, v: e2.1, w: 0 },
                    ])
                    .unwrap();
                assert!(!out.structural);

                let mut warm = SwapEngine::from_warm(&g2, &o, parts);
                warm.apply_deltas(&out.records);
                let ws = refiner
                    .refine_warm(&mut warm, &g2, &out.touched)
                    .expect("quiescent weight-only resume must be accepted");

                let mut cold = SwapEngine::new(&g2, &o, sigma_opt);
                let cs = mk(2).threads(t).refine(&mut cold, &g2, &mut Rng::new(1));

                assert_eq!(warm.mapping(), cold.mapping(), "rot={rot} t={t}");
                assert_eq!(warm.objective(), cold.objective(), "rot={rot} t={t}");
                assert_eq!(ws.improved, cs.improved, "rot={rot} t={t}");
                assert!(
                    ws.evaluated < cs.evaluated,
                    "partial re-seed must evaluate strictly less: rot={rot} t={t} \
                     {} vs {}",
                    ws.evaluated,
                    cs.evaluated
                );
                assert_eq!(warm.objective(), warm.recompute_objective());

                // empty-delta remap on the already-converged state: a pure
                // no-op — nothing evaluated, nothing moved
                let sigma_now = warm.mapping();
                let j_now = warm.objective();
                let ns = refiner
                    .refine_warm(&mut warm, &g2, &[])
                    .expect("empty-delta resume must be accepted");
                assert_eq!(ns.evaluated, 0);
                assert_eq!(ns.improved, 0);
                assert_eq!(warm.mapping(), sigma_now);
                assert_eq!(warm.objective(), j_now);
            }
        }
    }

    #[test]
    fn refine_warm_refuses_without_quiescence_or_after_structural_change() {
        use crate::graph::EdgeDelta;
        let (g, o) = setup(6, 150);
        let m = {
            let mut r = Rng::new(151);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut gc = GainCacheNc::new(2);
        let mut eng = SwapEngine::new(&g, &o, m);
        assert!(
            gc.refine_warm(&mut eng, &g, &[0, 1]).is_none(),
            "no prior drain: must refuse"
        );
        gc.refine(&mut eng, &g, &mut Rng::new(1));
        // a structural insert shifts the packed move-id space: must refuse
        let mut far = 1 as NodeId;
        while g.edge_weight(0, far).is_some() {
            far += 1;
        }
        let mut g2 = g.clone();
        let out = g2.apply_deltas(&[EdgeDelta { u: 0, v: far, w: 3 }]).unwrap();
        assert!(out.structural);
        let parts = eng.into_warm_parts();
        let mut warm = SwapEngine::from_warm(&g2, &o, parts);
        warm.apply_deltas(&out.records);
        assert!(
            gc.refine_warm(&mut warm, &g2, &out.touched).is_none(),
            "structural delta: must refuse and fall back to a full refine"
        );
        // the fallback full refine still works and re-arms quiescence
        gc.refine(&mut warm, &g2, &mut Rng::new(1));
        assert!(gc.refine_warm(&mut warm, &g2, &[]).is_some());
    }

    #[test]
    fn stats_account_for_seed_sweep_and_moves() {
        // evaluated ≥ |P| (+ 2|T| for the unified class — the seeding
        // sweep), one seeding round, and the improved count matches the
        // engine's applied-move counter — the strictly-fewer-than-shuffle
        // comparison is asserted where it is measured, in `ablation_ls`
        // and `hotpath --check`
        let (g, o) = setup(7, 92);
        let m = {
            let mut r = Rng::new(93);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut eng = SwapEngine::new(&g, &o, m.clone());
        let stats = GainCacheNc::new(1).refine(&mut eng, &g, &mut Rng::new(1));
        assert!(stats.evaluated >= g.m() as u64);
        assert_eq!(stats.rounds, 1);
        assert_eq!(stats.improved, eng.swaps_applied);

        let mut eng = SwapEngine::new(&g, &o, m);
        let stats = GainCacheNc::with_rotations(1).refine(&mut eng, &g, &mut Rng::new(1));
        assert!(stats.evaluated >= (g.m() + 2 * comm_triangles(&g).len()) as u64);
        assert_eq!(stats.rounds, 1);
        assert_eq!(stats.improved, eng.swaps_applied);
    }
}
