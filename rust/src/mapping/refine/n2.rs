//! Heider's full pair-exchange neighborhood `N²` (§2).

use super::{Refiner, SearchStats, Swapper};
use crate::graph::{Graph, NodeId};
use crate::util::{control, Rng, RunControl};

/// Cyclic `N²` search: all `O(n²)` pairs visited cyclically; a swap is
/// applied whenever it has positive gain; terminates when a full sweep
/// applies no swap (or after `max_sweeps` as a safety bound). The pair
/// universe is implicit in the index range.
#[derive(Debug, Clone, Default)]
pub struct N2Cyclic {
    /// Bound on the number of full passes.
    pub max_sweeps: usize,
    /// Anytime stop token ([`Refiner::set_control`]); disarmed by default.
    ctrl: RunControl,
}

impl N2Cyclic {
    pub fn new(max_sweeps: usize) -> N2Cyclic {
        N2Cyclic { max_sweeps, ctrl: RunControl::unlimited() }
    }
}

impl Refiner for N2Cyclic {
    fn name(&self) -> String {
        "N2".into()
    }

    fn set_control(&mut self, ctrl: &RunControl) {
        self.ctrl = ctrl.clone();
    }

    fn refine(&mut self, engine: &mut dyn Swapper, comm: &Graph, _rng: &mut Rng) -> SearchStats {
        let n = comm.n();
        let mut stats = SearchStats::default();
        let armed = self.ctrl.armed();
        'sweeps: for _ in 0..self.max_sweeps {
            stats.rounds += 1;
            let mut any = false;
            for i in 0..n as NodeId {
                for j in (i + 1)..n as NodeId {
                    stats.evaluated += 1;
                    if engine.try_swap(i, j).is_some() {
                        stats.improved += 1;
                        any = true;
                    }
                    if armed && stats.evaluated % control::CHECK_EVERY == 0 {
                        if let Some(r) = self.ctrl.stop_reason() {
                            stats.stopped = Some(r);
                            break 'sweeps;
                        }
                    }
                }
            }
            if !any {
                break;
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::model::topology::{Machine, Hierarchy};
    use crate::mapping::objective::{Mapping, SwapEngine};

    fn setup(nexp: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << nexp) / 64], vec![1, 10, 100]).unwrap();
        (g, Machine::implicit(h))
    }

    #[test]
    fn n2_reduces_objective_and_converges() {
        let (g, o) = setup(7, 3);
        let mut rng = Rng::new(4);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let before = eng.objective();
        let stats = N2Cyclic::new(50).refine(&mut eng, &g, &mut rng);
        let after = eng.objective();
        assert!(after < before, "{before} -> {after}");
        assert!(stats.rounds < 50, "did not converge");
        assert_eq!(after, eng.recompute_objective());
        // converged: no improving pair remains in the last sweep
        let final_stats = N2Cyclic::new(1).refine(&mut eng, &g, &mut rng);
        assert_eq!(final_stats.improved, 0);
    }
}
