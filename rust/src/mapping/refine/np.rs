//! Brandfass et al.'s pruned neighborhood `N_p` (§2).

use super::{Refiner, SearchStats, Swapper};
use crate::graph::{Graph, NodeId};
use crate::model::topology::Hierarchy;
use crate::util::{control, Rng, RunControl};

/// `N_p` search: the index space is partitioned into consecutive blocks of
/// `block_len` and only pairs inside a block are considered (`O(n·s)`
/// pairs), with same-leaf-group pairs skipped ("pairs for which the
/// objective cannot change"). The original chooses the block span to cover a
/// few compute nodes; callers pick `block_len`.
#[derive(Debug, Clone)]
pub struct NpBlocks {
    /// Pairs are only formed inside consecutive index blocks of this length.
    pub block_len: usize,
    /// Bound on the number of full passes.
    pub max_sweeps: usize,
    /// Machine hierarchy for the same-leaf-group skip rule; `None` disables
    /// the skip (every in-block pair is evaluated).
    hierarchy: Option<Hierarchy>,
    /// Anytime stop token ([`Refiner::set_control`]); disarmed by default.
    ctrl: RunControl,
}

impl NpBlocks {
    pub fn new(block_len: usize, max_sweeps: usize, hierarchy: Option<Hierarchy>) -> NpBlocks {
        NpBlocks {
            block_len: block_len.max(2),
            max_sweeps,
            hierarchy,
            ctrl: RunControl::unlimited(),
        }
    }
}

impl Refiner for NpBlocks {
    fn name(&self) -> String {
        "Np".into()
    }

    fn set_control(&mut self, ctrl: &RunControl) {
        self.ctrl = ctrl.clone();
    }

    fn refine(&mut self, engine: &mut dyn Swapper, comm: &Graph, _rng: &mut Rng) -> SearchStats {
        let n = comm.n();
        let block_len = self.block_len.max(2);
        let mut stats = SearchStats::default();
        let armed = self.ctrl.armed();
        'sweeps: for _ in 0..self.max_sweeps {
            stats.rounds += 1;
            let mut any = false;
            let mut start = 0usize;
            while start < n {
                let end = (start + block_len).min(n);
                for i in start..end {
                    for j in (i + 1)..end {
                        let (u, v) = (i as NodeId, j as NodeId);
                        if let Some(h) = &self.hierarchy {
                            // skip pairs that cannot change the objective
                            if h.same_leaf_group(engine.pe_of(u), engine.pe_of(v)) {
                                continue;
                            }
                        }
                        stats.evaluated += 1;
                        if engine.try_swap(u, v).is_some() {
                            stats.improved += 1;
                            any = true;
                        }
                        if armed && stats.evaluated % control::CHECK_EVERY == 0 {
                            if let Some(r) = self.ctrl.stop_reason() {
                                stats.stopped = Some(r);
                                break 'sweeps;
                            }
                        }
                    }
                }
                start = end;
            }
            if !any {
                break;
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::objective::{Mapping, SwapEngine};
    use crate::model::topology::Machine;

    fn setup(nexp: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << nexp) / 64], vec![1, 10, 100]).unwrap();
        (g, Machine::implicit(h))
    }

    #[test]
    fn np_reduces_objective() {
        let (g, o) = setup(8, 5);
        let mut rng = Rng::new(6);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let before = eng.objective();
        let h = Hierarchy::new(vec![4, 16, 4], vec![1, 10, 100]).unwrap();
        NpBlocks::new(64, 50, Some(h)).refine(&mut eng, &g, &mut rng);
        assert!(eng.objective() < before);
        assert!(eng.gamma_invariant_holds());
    }

    #[test]
    fn np_skips_same_leaf_pairs() {
        // engine on identity mapping with a single-level hierarchy: every
        // pair shares the one leaf group, so every pair is skipped.
        let (g, o) = setup(6, 12);
        let mut rng = Rng::new(13);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(g.n()));
        let h = Hierarchy::new(vec![64], vec![1]).unwrap(); // all PEs one group
        let stats = NpBlocks::new(8, 3, Some(h)).refine(&mut eng, &g, &mut rng);
        assert_eq!(stats.evaluated, 0, "all pairs share the single leaf group");
        assert_eq!(stats.improved, 0);
    }
}
