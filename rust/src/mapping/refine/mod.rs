//! Local-search refinement over swap neighborhoods (paper §2, §3.3),
//! unified behind the [`Refiner`] trait.
//!
//! This module replaces the former `mapping::local_search` free functions
//! (`n2_cyclic`, `np_blocks`, `nc_neighborhood`, `nc_search_in`,
//! `cycle3_search*`) with one trait and four concrete refiners:
//!
//! * [`N2Cyclic`] — Heider's full pair-exchange neighborhood `N²`.
//! * [`NpBlocks`] — Brandfass et al.'s pruned index-block neighborhood `N_p`.
//! * [`NcNeighborhood`] — this paper's communication-graph neighborhood
//!   `N_C^d` (owns and reuses the materialized pair set).
//! * [`Cycle3`] — cyclic exchange over communication-graph triangles (§5
//!   future work; owns and reuses the triangle set).
//! * [`GainCacheNc`] — the FM-style gain-cached search: a priority bucket
//!   queue with lazy, move-version-based invalidation, so moves untouched
//!   by an applied move are never re-evaluated (arXiv:2001.07134's k-way FM
//!   machinery on this paper's neighborhoods). Pair-only as `gc:nc<d>`; as
//!   `gc:nccyc<d>` ([`GainCacheNc::with_rotations`]) the *same queue* also
//!   holds both directions of every communication-graph triangle rotation —
//!   the unified move class pops the best of swap or 3-cycle, instead of
//!   parking rotations behind pair-swap convergence like the phased
//!   [`NcCycle`].
//!
//! Each refiner owns its reusable scratch — pair sets, triangle sets and
//! shuffle buffers that used to be cached ad hoc inside
//! [`crate::api::MapSession`] — so both the flat session path and the
//! multilevel V-cycle ([`crate::mapping::multilevel`]) reuse them by simply
//! keeping the refiner alive across repetitions (and across V-cycle levels:
//! one refiner per level).
//!
//! All refiners drive a `&mut dyn` [`Swapper`], so the identical search
//! trajectory runs under both the fast `O(d_u + d_v)` [`SwapEngine`] and the
//! dense `O(n)` [`DenseEngine`] baseline (Table 1's premise) — including the
//! 3-cycle rotations, which both engines now support via
//! [`Swapper::try_rotate3`].

pub mod cycle;
pub mod gaincache;
pub mod n2;
pub mod nc;
pub mod np;

pub use cycle::{comm_triangles, Cycle3, NcCycle, TriangleSet};
pub use gaincache::{GainBucketQueue, GainCacheNc};
pub use n2::N2Cyclic;
pub use nc::{nc_neighborhood, nc_pairs, NcNeighborhood};
pub use np::NpBlocks;

use super::algorithms::Neighborhood;
use super::objective::{DenseEngine, SwapEngine};
use crate::graph::{Graph, NodeId};
use crate::model::topology::{Hierarchy, Machine};
use crate::util::{Rng, RunControl, StopReason};

/// Common interface over the fast (sparse, `O(d_u+d_v)`) and slow (dense,
/// `O(n)`) swap engines.
///
/// `Sync` is a supertrait so a `&dyn Swapper` can be shared across the
/// scoped worker threads of the parallel gain-cache search and the parallel
/// V-cycle subtree phase: every in-tree engine is plain data (`Vec`s plus
/// shared `&Graph`/`&Machine` borrows), so the bound costs nothing and buys
/// read-only parallel gain evaluation.
pub trait Swapper: Sync {
    /// Gain of swapping `u` and `v` *without* applying (positive = the
    /// objective would decrease by that amount).
    fn swap_gain(&self, u: NodeId, v: NodeId) -> i64;
    /// Apply the swap unconditionally (the caller has already decided).
    fn do_swap(&mut self, u: NodeId, v: NodeId);
    /// Apply a swap whose *exact* gain the caller already knows — a
    /// gain-cached refiner pops a pair whose stamped gain is provably
    /// fresh. Defaults to [`Self::do_swap`], which is already
    /// `O(d_u + d_v)` for the sparse engine; the dense engine overrides it
    /// to skip the second `O(n)` row scan its `do_swap` would pay just to
    /// recompute the gain. Passing a wrong gain corrupts the objective.
    fn do_swap_with_gain(&mut self, u: NodeId, v: NodeId, _gain: i64) {
        self.do_swap(u, v)
    }
    /// Apply the swap iff it strictly improves the objective.
    fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64>;
    /// Current objective value.
    fn objective(&self) -> u64;
    /// PE currently hosting process `u`.
    fn pe_of(&self, u: NodeId) -> u32;
    /// Apply the 3-cycle rotation `u -> v -> w -> u` iff it strictly
    /// improves. Default-unsupported: engines that lack rotation machinery
    /// inherit a no-op that never moves (and must leave
    /// [`Self::supports_rotate3`] false so [`Cycle3`] can skip them).
    fn try_rotate3(&mut self, _u: NodeId, _v: NodeId, _w: NodeId) -> Option<i64> {
        None
    }
    /// Gain of the 3-cycle rotation `u -> v -> w -> u` *without* applying
    /// (positive = the objective would decrease by that amount). The
    /// unified gain-cache queue evaluates rotations through this hook
    /// exactly like pair gains through [`Self::swap_gain`].
    /// Default-unsupported: never improving, paired with the
    /// [`Self::try_rotate3`] no-op.
    fn rotate3_gain(&self, _u: NodeId, _v: NodeId, _w: NodeId) -> i64 {
        0
    }
    /// Apply the rotation unconditionally (the caller has already decided).
    /// Engines advertising [`Self::supports_rotate3`] MUST override this
    /// (both in-tree engines do); the default panics rather than silently
    /// not moving — a no-op here would leave the gain-cache queue popping
    /// the same "applied" rotation forever. Unreachable through the
    /// refiners for engines that keep `supports_rotate3` false.
    fn do_rotate3(&mut self, _u: NodeId, _v: NodeId, _w: NodeId) {
        panic!(
            "Swapper::do_rotate3 not overridden — an engine with \
             supports_rotate3() == true must implement the rotation apply"
        )
    }
    /// Apply a rotation whose *exact* gain the caller already knows — the
    /// unified gain-cache refiner pops a rotation whose stamped gain is
    /// provably fresh. Defaults to [`Self::do_rotate3`], which is already
    /// `O(d_u + d_v + d_w)` for the sparse engine; the dense engine
    /// overrides it to its `O(1)` apply, skipping the `O(n)` row scan its
    /// `do_rotate3` would burn recomputing the gain. Passing a wrong gain
    /// corrupts the objective.
    fn do_rotate3_with_gain(&mut self, u: NodeId, v: NodeId, w: NodeId, _gain: i64) {
        self.do_rotate3(u, v, w)
    }
    /// True when [`Self::try_rotate3`] actually evaluates rotations.
    fn supports_rotate3(&self) -> bool {
        false
    }
    /// Move version of `u`: bumped by every applied move that can change a
    /// gain involving `u` (the endpoints and all their communication
    /// neighbors). u64 so stamps built from it can never alias after
    /// wraparound. Inert default for engines without version tracking —
    /// they must leave [`Self::supports_versions`] false so gain-cached
    /// refiners fall back to epoch-based invalidation.
    fn version_of(&self, _u: NodeId) -> u64 {
        0
    }
    /// True when [`Self::version_of`] actually tracks moves.
    fn supports_versions(&self) -> bool {
        false
    }
}

impl Swapper for SwapEngine<'_> {
    fn swap_gain(&self, u: NodeId, v: NodeId) -> i64 {
        SwapEngine::swap_gain(self, u, v)
    }
    fn do_swap(&mut self, u: NodeId, v: NodeId) {
        SwapEngine::do_swap(self, u, v)
    }
    fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
        SwapEngine::try_swap(self, u, v)
    }
    fn objective(&self) -> u64 {
        SwapEngine::objective(self)
    }
    fn pe_of(&self, u: NodeId) -> u32 {
        SwapEngine::pe_of(self, u)
    }
    fn try_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) -> Option<i64> {
        SwapEngine::try_rotate3(self, u, v, w)
    }
    fn rotate3_gain(&self, u: NodeId, v: NodeId, w: NodeId) -> i64 {
        SwapEngine::rotate3_gain(self, u, v, w)
    }
    fn do_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) {
        SwapEngine::do_rotate3(self, u, v, w)
    }
    fn supports_rotate3(&self) -> bool {
        true
    }
    fn version_of(&self, u: NodeId) -> u64 {
        SwapEngine::version_of(self, u)
    }
    fn supports_versions(&self) -> bool {
        true
    }
}

impl Swapper for DenseEngine {
    fn swap_gain(&self, u: NodeId, v: NodeId) -> i64 {
        DenseEngine::swap_gain(self, u, v)
    }
    fn do_swap(&mut self, u: NodeId, v: NodeId) {
        DenseEngine::do_swap(self, u, v)
    }
    fn do_swap_with_gain(&mut self, u: NodeId, v: NodeId, gain: i64) {
        DenseEngine::apply_swap_with_gain(self, u, v, gain)
    }
    fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
        DenseEngine::try_swap(self, u, v)
    }
    fn objective(&self) -> u64 {
        DenseEngine::objective(self)
    }
    fn pe_of(&self, u: NodeId) -> u32 {
        DenseEngine::pe_of(self, u)
    }
    fn try_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) -> Option<i64> {
        DenseEngine::try_rotate3(self, u, v, w)
    }
    fn rotate3_gain(&self, u: NodeId, v: NodeId, w: NodeId) -> i64 {
        DenseEngine::rotate3_gain(self, u, v, w)
    }
    fn do_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) {
        DenseEngine::do_rotate3(self, u, v, w)
    }
    fn do_rotate3_with_gain(&mut self, u: NodeId, v: NodeId, w: NodeId, gain: i64) {
        DenseEngine::apply_rotate3_with_gain(self, u, v, w, gain)
    }
    fn supports_rotate3(&self) -> bool {
        true
    }
    // version_of / supports_versions: inert defaults — the dense baseline
    // has no incremental bookkeeping to version; GainCacheNc falls back to
    // its own applied-move epoch for staleness.
}

/// Search statistics returned by every refiner.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Pairs/rotations evaluated (gain computations).
    pub evaluated: u64,
    /// Moves applied.
    pub improved: u64,
    /// Full sweeps/rounds executed.
    pub rounds: u64,
    /// Why the search stopped before natural convergence, if it did
    /// ([`Refiner::set_control`]); `None` for every uncontrolled run, so
    /// the no-deadline bit-identity comparisons are unaffected.
    pub stopped: Option<StopReason>,
}

impl SearchStats {
    /// Accumulate another refiner's statistics (used when refiners compose,
    /// e.g. [`NcCycle`], and by the V-cycle's per-repetition aggregate).
    pub fn absorb(&mut self, other: &SearchStats) {
        self.evaluated += other.evaluated;
        self.improved += other.improved;
        self.rounds += other.rounds;
        self.stopped = self.stopped.or(other.stopped);
    }
}

/// A local-search refinement pass: drive `engine` (which holds the current
/// assignment of `comm`'s processes) to a local optimum of the refiner's
/// neighborhood. Implementations own their reusable scratch; a refiner
/// instance is bound to the one communication graph it first refines
/// (subsequent calls reuse the cached pair/triangle sets).
///
/// `Send` is a supertrait so boxed refiners (session scratch, the per-level
/// V-cycle vector) can move into scoped worker threads for parallel
/// repetitions and parallel subtree refinement. All in-tree refiners own
/// only plain data (`Vec`s, counters), so the bound is free.
pub trait Refiner: Send {
    /// Human-readable name (for benches and logs).
    fn name(&self) -> String;
    /// Run the search to convergence; never increases `engine.objective()`.
    fn refine(&mut self, engine: &mut dyn Swapper, comm: &Graph, rng: &mut Rng) -> SearchStats;
    /// Install a [`RunControl`] token: subsequent [`Self::refine`] calls
    /// check it every [`crate::util::control::CHECK_EVERY`] iterations and
    /// stop at the next move boundary once it fires, reporting the reason
    /// in [`SearchStats::stopped`]. Every concrete refiner overrides this
    /// (the anytime contract); the default keeps third-party refiners
    /// compiling — they simply run to convergence. A disarmed token
    /// restores the zero-overhead uncontrolled behavior.
    fn set_control(&mut self, _ctrl: &RunControl) {}
    /// Warm-started refinement for the REMAP path: the engine was
    /// resurrected at this refiner's own previous local optimum and then
    /// delta-patched ([`SwapEngine::apply_deltas`]), and `touched` lists the
    /// vertices whose incident edge weights changed. A refiner that keeps
    /// enough state to resume — today only [`GainCacheNc`], whose persisted
    /// gain/stamp arrays are exact at a completed drain — re-seeds just the
    /// moves incident to `touched` and drains from there, returning
    /// `Some(stats)`. The default (and any refiner whose preconditions are
    /// not met) returns `None`, telling the caller to fall back to a full
    /// [`Self::refine`].
    fn refine_warm(
        &mut self,
        _engine: &mut dyn Swapper,
        _comm: &Graph,
        _touched: &[NodeId],
    ) -> Option<SearchStats> {
        None
    }
}

/// The no-op refiner ([`Neighborhood::None`]): construction-only specs run
/// through the same code path as everything else.
#[derive(Debug, Clone, Copy, Default)]
pub struct Noop;

impl Refiner for Noop {
    fn name(&self) -> String {
        "none".into()
    }
    fn refine(&mut self, _engine: &mut dyn Swapper, _comm: &Graph, _rng: &mut Rng) -> SearchStats {
        SearchStats::default()
    }
}

/// Instantiate the refiner for a [`Neighborhood`]. `machine` is the
/// topology the engine maps onto — the `N_p` pair-skip rule needs its
/// hierarchy (ultrametric leaf groups; grid/torus/explicit machines have
/// none, so `N_p` simply skips nothing there); in the multilevel V-cycle
/// each level passes its *folded* machine.
pub fn refiner_for(
    neighborhood: Neighborhood,
    max_sweeps: usize,
    machine: &Machine,
) -> Box<dyn Refiner> {
    refiner_for_threads(neighborhood, max_sweeps, machine, 1)
}

/// [`refiner_for`] with an intra-refiner worker-thread count. Only the
/// gain-cached refiners parallelize internally (the seeding sweep and the
/// drain of [`GainCacheNc`], in its deterministic bit-identical-to-`T=1`
/// mode); the sweep-based refiners ignore the knob — they already get
/// their parallelism from the coarser layers (parallel repetitions and
/// V-cycle subtrees).
pub fn refiner_for_threads(
    neighborhood: Neighborhood,
    max_sweeps: usize,
    machine: &Machine,
    threads: usize,
) -> Box<dyn Refiner> {
    match neighborhood {
        Neighborhood::None => Box::new(Noop),
        Neighborhood::N2 => Box::new(N2Cyclic::new(max_sweeps)),
        Neighborhood::Np { block_len } => {
            Box::new(NpBlocks::new(block_len, max_sweeps, machine.hier().cloned()))
        }
        Neighborhood::Nc { d } => Box::new(NcNeighborhood::new(d)),
        Neighborhood::NcCycle { d } => Box::new(NcCycle::new(d, max_sweeps)),
        Neighborhood::GcNc { d } => Box::new(GainCacheNc::new(d).threads(threads)),
        Neighborhood::GcNcCycle { d } => Box::new(GainCacheNc::with_rotations(d).threads(threads)),
    }
}

/// Fingerprint a graph for the scratch caches: refiners rebuild their pair /
/// triangle sets when the graph they are asked to refine changes. Size
/// alone is not enough (two same-family instances can share `(n, m)` with
/// different edges), so the key also folds every edge endpoint and weight
/// through FNV-1a. `O(n + m)` — negligible next to any search, which
/// evaluates at least `m` gain computations of `O(deg)` each. (Within a
/// session or V-cycle each refiner only ever sees one graph; the
/// fingerprint turns accidental cross-graph reuse into a rebuild instead of
/// a silent wrong-pair-set search.)
pub(crate) fn graph_key(comm: &Graph) -> (usize, usize, u64) {
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x1_0000_0001_b3); // FNV prime
    };
    for u in 0..comm.n() as NodeId {
        for (v, w) in comm.edges(u) {
            if v > u {
                mix(u as u64);
                mix(v as u64);
                mix(w);
            }
        }
    }
    (comm.n(), comm.m(), h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::model::topology::Machine;
    use crate::mapping::objective::Mapping;

    pub(crate) fn setup(nexp: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << nexp) / 64], vec![1, 10, 100]).unwrap();
        (g, Machine::implicit(h))
    }

    #[test]
    fn factory_covers_every_neighborhood() {
        let h = Hierarchy::new(vec![4, 16, 2], vec![1, 10, 100]).unwrap();
        let machines = [Machine::Hier(h), Machine::parse("grid:16x8@1").unwrap()];
        for machine in &machines {
            for (nb, name) in [
                (Neighborhood::None, "none"),
                (Neighborhood::N2, "N2"),
                (Neighborhood::Np { block_len: 64 }, "Np"),
                (Neighborhood::Nc { d: 3 }, "Nc3"),
                (Neighborhood::NcCycle { d: 2 }, "NcCyc2"),
                (Neighborhood::GcNc { d: 3 }, "GcNc3"),
                (Neighborhood::GcNcCycle { d: 2 }, "GcNcCyc2"),
            ] {
                assert_eq!(refiner_for(nb, 100, machine).name(), name, "{}", machine.kind());
            }
        }
    }

    #[test]
    fn engines_and_refiners_cross_threads() {
        // the Send/Sync refactor, statically: engines are shareable across
        // the scoped workers of the parallel drains, and boxed refiners
        // move into the scoped workers of parallel repetitions / subtrees
        fn assert_send<T: Send + ?Sized>() {}
        fn assert_sync<T: Sync + ?Sized>() {}
        assert_sync::<crate::mapping::objective::SwapEngine<'_>>();
        assert_sync::<crate::mapping::objective::DenseEngine>();
        assert_sync::<dyn Swapper>();
        assert_send::<Box<dyn Refiner>>();
        assert_send::<GainCacheNc>();
    }

    #[test]
    fn noop_refiner_never_moves() {
        let (g, o) = setup(6, 40);
        let mut rng = Rng::new(41);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut eng = crate::mapping::objective::SwapEngine::new(&g, &o, m);
        let before = eng.objective();
        let stats = Noop.refine(&mut eng, &g, &mut rng);
        assert_eq!(eng.objective(), before);
        assert_eq!(stats, SearchStats::default());
    }

    #[test]
    fn dense_and_sparse_follow_identical_trajectory() {
        // Table 1's premise: same visit order => same swaps => same final
        // objective, only the running time differs.
        let (g, o) = setup(6, 13);
        let mut rng = Rng::new(14);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut fast = crate::mapping::objective::SwapEngine::new(&g, &o, m.clone());
        let mut slow = crate::mapping::objective::DenseEngine::new(&g, &o, m);
        let mut r = N2Cyclic::new(10);
        let mut rng_a = Rng::new(15);
        let mut rng_b = Rng::new(15);
        let sf = r.refine(&mut fast, &g, &mut rng_a);
        let ss = r.refine(&mut slow, &g, &mut rng_b);
        assert_eq!(fast.objective(), slow.objective());
        assert_eq!(sf, ss);
    }

    #[test]
    fn dense_and_sparse_identical_under_cyclic_search() {
        // the former concrete-SwapEngine-only special-casing is gone: the
        // triangle-rotation search follows the same trajectory under both
        // gain engines through the Swapper trait
        let (g, o) = setup(6, 50);
        let mut rng = Rng::new(51);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut fast = crate::mapping::objective::SwapEngine::new(&g, &o, m.clone());
        let mut slow = crate::mapping::objective::DenseEngine::new(&g, &o, m);
        let mut ra = NcCycle::new(1, 50);
        let mut rb = NcCycle::new(1, 50);
        let mut rng_a = Rng::new(52);
        let mut rng_b = Rng::new(52);
        let sf = ra.refine(&mut fast, &g, &mut rng_a);
        let ss = rb.refine(&mut slow, &g, &mut rng_b);
        assert_eq!(fast.objective(), slow.objective());
        assert_eq!(sf, ss);
        assert_eq!(fast.mapping(), slow.mapping());
    }
}
