//! Cyclic-exchange search over communication-graph *triangles* (the paper's
//! §5 future work: "allow swapping along cycles in the communication
//! graph").

use super::nc::NcNeighborhood;
use super::{graph_key, Refiner, SearchStats, Swapper};
use crate::graph::{Graph, NodeId};
use crate::util::{control, Rng, RunControl};

/// Enumerate the triangles `u < v < w` of `comm` (for each edge `(u,v)`,
/// intersect the sorted adjacencies).
pub fn comm_triangles(comm: &Graph) -> Vec<(NodeId, NodeId, NodeId)> {
    let mut triangles: Vec<(NodeId, NodeId, NodeId)> = Vec::new();
    for u in 0..comm.n() as NodeId {
        for &v in comm.neighbors(u) {
            if v <= u {
                continue;
            }
            // sorted adjacency intersection
            let (mut i, mut j) = (0usize, 0usize);
            let nu = comm.neighbors(u);
            let nv = comm.neighbors(v);
            while i < nu.len() && j < nv.len() {
                match nu[i].cmp(&nv[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        if nu[i] > v {
                            triangles.push((u, v, nu[i]));
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }
    triangles
}

/// Graph-keyed cache of the canonical [`comm_triangles`] set, shared by
/// [`Cycle3`] and the unified gain cache
/// ([`super::GainCacheNc::with_rotations`]) so both move classes search the
/// identical canonical triangle enumeration (rebuilt only when the refined
/// graph changes, like every refiner's scratch).
#[derive(Debug, Clone, Default)]
pub struct TriangleSet {
    cache: Option<((usize, usize, u64), Vec<(NodeId, NodeId, NodeId)>)>,
}

impl TriangleSet {
    /// The canonical triangle set of `comm` (`u < v < w` order), filling or
    /// refreshing the cache as needed.
    pub fn get(&mut self, comm: &Graph) -> &[(NodeId, NodeId, NodeId)] {
        let key = graph_key(comm);
        let stale = match &self.cache {
            Some((cached, _)) => *cached != key,
            None => true,
        };
        if stale {
            self.cache = Some((key, comm_triangles(comm)));
        }
        &self.cache.as_ref().unwrap().1
    }

    /// Re-key the cached triangle set to `comm` *without* re-enumerating.
    /// [`graph_key`] folds edge weights, so a weight-only delta batch
    /// (REMAP's warm path) changes the key while leaving the triangle
    /// *structure* — which is all this set records — untouched. The caller
    /// guarantees exactly that; a structural change must go through
    /// [`Self::get`], which rebuilds. Returns false (and retags nothing)
    /// when the cache is empty.
    pub fn retag(&mut self, comm: &Graph) -> bool {
        match &mut self.cache {
            Some((cached, _)) => {
                *cached = graph_key(comm);
                true
            }
            None => false,
        }
    }
}

/// Triangle-rotation search: enumerate the triangles of `G_C`, try both
/// rotation directions, apply strictly improving ones; repeat until a full
/// pass finds nothing (or `max_rounds`). Owns the triangle set and a
/// shuffled working copy, rebuilt only when the refined graph changes.
///
/// Runs under any engine whose [`Swapper::supports_rotate3`] is true (both
/// in-tree engines); engines inheriting the default-unsupported rotation are
/// skipped entirely (zero evaluations) rather than burning a no-op pass.
#[derive(Debug, Clone)]
pub struct Cycle3 {
    /// Bound on the number of full passes.
    pub max_rounds: usize,
    set: TriangleSet,
    work: Vec<(NodeId, NodeId, NodeId)>,
    /// Anytime stop token ([`Refiner::set_control`]); disarmed by default.
    ctrl: RunControl,
}

impl Cycle3 {
    pub fn new(max_rounds: usize) -> Cycle3 {
        Cycle3 {
            max_rounds,
            set: TriangleSet::default(),
            work: Vec::new(),
            ctrl: RunControl::unlimited(),
        }
    }

    fn fill_work(&mut self, comm: &Graph) {
        let canonical = self.set.get(comm);
        self.work.clear();
        self.work.extend_from_slice(canonical);
    }

    /// The search loop over a caller-provided triangle set (shuffled in
    /// place). Exposed for ablation harnesses.
    pub fn search_in(
        engine: &mut dyn Swapper,
        triangles: &mut [(NodeId, NodeId, NodeId)],
        rng: &mut Rng,
        max_rounds: usize,
    ) -> SearchStats {
        Self::search_in_controlled(engine, triangles, rng, max_rounds, &RunControl::unlimited())
    }

    /// [`Self::search_in`] under a [`RunControl`]: checked every
    /// [`control::CHECK_EVERY`] evaluations, stopping at a rotation
    /// boundary. Disarmed tokens take the exact uncontrolled trajectory.
    pub fn search_in_controlled(
        engine: &mut dyn Swapper,
        triangles: &mut [(NodeId, NodeId, NodeId)],
        rng: &mut Rng,
        max_rounds: usize,
        ctrl: &RunControl,
    ) -> SearchStats {
        let mut stats = SearchStats::default();
        if triangles.is_empty() {
            return stats;
        }
        rng.shuffle(triangles);
        let armed = ctrl.armed();
        'rounds: for _ in 0..max_rounds {
            stats.rounds += 1;
            let mut any = false;
            for &(u, v, w) in triangles.iter() {
                // both rotation directions; the second is only evaluated
                // (and only counted) when the first does not apply
                stats.evaluated += 1;
                let hit = engine.try_rotate3(u, v, w).is_some() || {
                    stats.evaluated += 1;
                    engine.try_rotate3(u, w, v).is_some()
                };
                if hit {
                    stats.improved += 1;
                    any = true;
                }
                if armed && stats.evaluated % control::CHECK_EVERY <= 1 {
                    // `<= 1` because the two-direction probe can step the
                    // counter by 2 and jump over the exact multiple
                    if let Some(r) = ctrl.stop_reason() {
                        stats.stopped = Some(r);
                        break 'rounds;
                    }
                }
            }
            if !any {
                break;
            }
        }
        stats
    }
}

impl Refiner for Cycle3 {
    fn name(&self) -> String {
        "Cyc3".into()
    }

    fn set_control(&mut self, ctrl: &RunControl) {
        self.ctrl = ctrl.clone();
    }

    fn refine(&mut self, engine: &mut dyn Swapper, comm: &Graph, rng: &mut Rng) -> SearchStats {
        if !engine.supports_rotate3() {
            return SearchStats::default();
        }
        self.fill_work(comm);
        let ctrl = self.ctrl.clone();
        Self::search_in_controlled(engine, &mut self.work, rng, self.max_rounds, &ctrl)
    }
}

/// The registry's `+NcCyc<d>`: `N_C^d` pair swaps to convergence, then
/// triangle rotations (a strictly larger move class; never worsens).
#[derive(Debug, Clone)]
pub struct NcCycle {
    nc: NcNeighborhood,
    cyc: Cycle3,
}

impl NcCycle {
    pub fn new(d: u32, max_rounds: usize) -> NcCycle {
        NcCycle { nc: NcNeighborhood::new(d), cyc: Cycle3::new(max_rounds) }
    }
}

impl Refiner for NcCycle {
    fn name(&self) -> String {
        format!("NcCyc{}", self.nc.d)
    }

    fn set_control(&mut self, ctrl: &RunControl) {
        self.nc.set_control(ctrl);
        self.cyc.set_control(ctrl);
    }

    fn refine(&mut self, engine: &mut dyn Swapper, comm: &Graph, rng: &mut Rng) -> SearchStats {
        let mut stats = self.nc.refine(engine, comm, rng);
        if stats.stopped.is_some() {
            // pair phase hit the deadline/cancel — don't start rotations
            return stats;
        }
        stats.absorb(&self.cyc.refine(engine, comm, rng));
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::objective::{Mapping, SwapEngine};
    use crate::mapping::refine::nc_neighborhood;
    use crate::model::topology::{Hierarchy, Machine};

    fn setup(nexp: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << nexp) / 64], vec![1, 10, 100]).unwrap();
        (g, Machine::implicit(h))
    }

    #[test]
    fn cycle3_improves_beyond_pair_swaps() {
        // after N_C^1 pair-swap convergence, triangle rotations may still
        // find gains (a strictly larger move class); never worsen.
        let (g, o) = setup(8, 17);
        let mut rng = Rng::new(18);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        nc_neighborhood(&mut eng, &g, 1, &mut rng, u64::MAX);
        let after_pairs = eng.objective();
        let stats = Cycle3::new(50).refine(&mut eng, &g, &mut rng);
        assert!(eng.objective() <= after_pairs);
        assert!(stats.evaluated > 0, "rgg comm graphs contain triangles");
        assert_eq!(eng.objective(), eng.recompute_objective());
    }

    #[test]
    fn cycle3_on_triangle_free_graph_is_noop() {
        // a path graph has no triangles
        let g = crate::graph::from_edges(
            6,
            &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (3, 4, 1), (4, 5, 1)],
        );
        let h = Hierarchy::new(vec![2, 3], vec![1, 10]).unwrap();
        let o = Machine::implicit(h);
        let mut rng = Rng::new(19);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(6));
        let stats = Cycle3::new(10).refine(&mut eng, &g, &mut rng);
        assert_eq!(stats.evaluated, 0);
    }

    #[test]
    fn unsupported_engine_is_skipped() {
        // an engine that keeps the default-unsupported rotation gets zero
        // evaluations instead of a futile pass over every triangle
        struct PairsOnly(u64);
        impl Swapper for PairsOnly {
            fn swap_gain(&self, _u: NodeId, _v: NodeId) -> i64 {
                0
            }
            fn do_swap(&mut self, _u: NodeId, _v: NodeId) {}
            fn try_swap(&mut self, _u: NodeId, _v: NodeId) -> Option<i64> {
                None
            }
            fn objective(&self) -> u64 {
                self.0
            }
            fn pe_of(&self, u: NodeId) -> u32 {
                u
            }
        }
        let (g, _) = setup(6, 20);
        let mut rng = Rng::new(21);
        let mut eng = PairsOnly(7);
        let stats = Cycle3::new(10).refine(&mut eng, &g, &mut rng);
        assert_eq!(stats, SearchStats::default());
        assert_eq!(eng.try_rotate3(0, 1, 2), None, "default rotation is a no-op");
    }

    #[test]
    fn kept_alive_cached_triangles_match_fresh() {
        let (g, o) = setup(7, 33);
        let m = {
            let mut r = Rng::new(34);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut refiner = Cycle3::new(20);
        {
            let mut warm_rng = Rng::new(98);
            let mut warm = SwapEngine::new(&g, &o, m.clone());
            refiner.refine(&mut warm, &g, &mut warm_rng);
        }
        let mut rng_a = Rng::new(35);
        let mut e1 = SwapEngine::new(&g, &o, m.clone());
        let s1 = refiner.refine(&mut e1, &g, &mut rng_a);

        let mut rng_b = Rng::new(35);
        let mut e2 = SwapEngine::new(&g, &o, m);
        let mut tris = comm_triangles(&g);
        let s2 = Cycle3::search_in(&mut e2, &mut tris, &mut rng_b, 20);

        assert_eq!(e1.objective(), e2.objective());
        assert_eq!(s1, s2);
    }
}
