//! Local search over swap neighborhoods (paper §2, §3.3).
//!
//! * [`n2_cyclic`] — Heider's full pair-exchange neighborhood `N²`: all
//!   `O(n²)` pairs visited cyclically; a swap is applied whenever it has
//!   positive gain; terminates when a full cycle applies no swap.
//! * [`np_blocks`] — Brandfass et al.'s pruned neighborhood `N_p`: the index
//!   space is partitioned into `s` consecutive blocks and only pairs inside
//!   a block are considered (`O(n·s)` pairs), with the same-leaf-group pairs
//!   skipped ("pairs for which the objective cannot change").
//! * [`nc_neighborhood`] — this paper's communication-graph neighborhoods
//!   `N_C^d`: only pairs of processes within graph distance `d` in `G_C` may
//!   swap; pairs are tried in random order and the search stops after a full
//!   round of consecutive unsuccessful attempts.
//!
//! All engines work on either the fast [`SwapEngine`] or the slow
//! [`DenseEngine`] through the [`Swapper`] trait, so Table 1 can time the
//! identical search trajectory under both gain computations.

use super::objective::{DenseEngine, SwapEngine};
use crate::graph::{bfs_ball, Graph, NodeId};
use crate::mapping::hierarchy::Hierarchy;
use crate::util::Rng;

/// Common interface over the fast (sparse, `O(d_u+d_v)`) and slow (dense,
/// `O(n)`) swap engines.
pub trait Swapper {
    /// Apply the swap iff it strictly improves the objective.
    fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64>;
    /// Current objective value.
    fn objective(&self) -> u64;
}

impl Swapper for SwapEngine<'_> {
    fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
        SwapEngine::try_swap(self, u, v)
    }
    fn objective(&self) -> u64 {
        SwapEngine::objective(self)
    }
}

impl Swapper for DenseEngine {
    fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
        DenseEngine::try_swap(self, u, v)
    }
    fn objective(&self) -> u64 {
        DenseEngine::objective(self)
    }
}

/// Search statistics returned by every local search.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    /// Pairs evaluated (gain computations).
    pub evaluated: u64,
    /// Swaps applied.
    pub improved: u64,
    /// Full sweeps/rounds executed.
    pub rounds: u64,
}

/// Heider's cyclic `N²` pair-exchange search. `max_sweeps` bounds the number
/// of full passes (the algorithm converges when a sweep applies no swap).
pub fn n2_cyclic<S: Swapper>(engine: &mut S, n: usize, max_sweeps: usize) -> SearchStats {
    let mut stats = SearchStats::default();
    for _ in 0..max_sweeps {
        stats.rounds += 1;
        let mut any = false;
        for i in 0..n as NodeId {
            for j in (i + 1)..n as NodeId {
                stats.evaluated += 1;
                if engine.try_swap(i, j).is_some() {
                    stats.improved += 1;
                    any = true;
                }
            }
        }
        if !any {
            break;
        }
    }
    stats
}

/// Brandfass et al.'s pruned neighborhood `N_p`: `s` consecutive index
/// blocks, pairs only within a block, same-leaf-group pairs skipped.
/// The original chooses `s` so each block spans a few compute nodes; we
/// default to blocks of `4 × a₁·a₂`-ish — callers pass `block_len`.
pub fn np_blocks<S: Swapper>(
    engine: &mut S,
    n: usize,
    block_len: usize,
    hierarchy: Option<&Hierarchy>,
    pe_of: impl Fn(&S, NodeId) -> u32,
    max_sweeps: usize,
) -> SearchStats {
    let mut stats = SearchStats::default();
    let block_len = block_len.max(2);
    for _ in 0..max_sweeps {
        stats.rounds += 1;
        let mut any = false;
        let mut start = 0usize;
        while start < n {
            let end = (start + block_len).min(n);
            for i in start..end {
                for j in (i + 1)..end {
                    let (u, v) = (i as NodeId, j as NodeId);
                    if let Some(h) = hierarchy {
                        // skip pairs that cannot change the objective
                        if h.same_leaf_group(pe_of(engine, u), pe_of(engine, v)) {
                            continue;
                        }
                    }
                    stats.evaluated += 1;
                    if engine.try_swap(u, v).is_some() {
                        stats.improved += 1;
                        any = true;
                    }
                }
            }
            start = end;
        }
        if !any {
            break;
        }
    }
    stats
}

/// Materialize the pair set of the `N_C^d` neighborhood: all unordered pairs
/// of distinct processes within communication-graph distance `d`.
/// For `d = 1` this is exactly the edge set (size `m`).
pub fn nc_pairs(comm: &Graph, d: u32) -> Vec<(NodeId, NodeId)> {
    let n = comm.n();
    let mut pairs = Vec::new();
    if d <= 1 {
        for u in 0..n as NodeId {
            for &v in comm.neighbors(u) {
                if v > u {
                    pairs.push((u, v));
                }
            }
        }
        return pairs;
    }
    let mut scratch = vec![u32::MAX; n];
    let mut queue = Vec::new();
    for u in 0..n as NodeId {
        for v in bfs_ball(comm, u, d, &mut scratch, &mut queue) {
            if v > u {
                pairs.push((u, v));
            }
        }
    }
    pairs
}

/// `N_C^d` local search: random order over the pair set, terminating after
/// `pairs.len()` consecutive unsuccessful swaps (§3.3).
pub fn nc_neighborhood<S: Swapper>(
    engine: &mut S,
    comm: &Graph,
    d: u32,
    rng: &mut Rng,
    max_evaluations: u64,
) -> SearchStats {
    let mut pairs = nc_pairs(comm, d);
    nc_search_in(engine, &mut pairs, rng, max_evaluations)
}

/// The search loop of [`nc_neighborhood`] over a caller-provided pair set.
/// Materializing `N_C^d` costs a BFS ball per vertex; callers that run many
/// repetitions on one instance ([`crate::api::MapSession`]) compute the pair
/// set once and pass a reusable working copy here. The slice is shuffled in
/// place (identical trajectory to [`nc_neighborhood`] for the same RNG).
pub fn nc_search_in<S: Swapper>(
    engine: &mut S,
    pairs: &mut [(NodeId, NodeId)],
    rng: &mut Rng,
    max_evaluations: u64,
) -> SearchStats {
    let mut stats = SearchStats::default();
    if pairs.is_empty() {
        return stats;
    }
    rng.shuffle(pairs);
    let threshold = pairs.len() as u64;
    let mut consecutive_failures = 0u64;
    let mut idx = 0usize;
    while consecutive_failures < threshold && stats.evaluated < max_evaluations {
        let (u, v) = pairs[idx];
        stats.evaluated += 1;
        if engine.try_swap(u, v).is_some() {
            stats.improved += 1;
            consecutive_failures = 0;
        } else {
            consecutive_failures += 1;
        }
        idx += 1;
        if idx == pairs.len() {
            idx = 0;
            stats.rounds += 1;
            rng.shuffle(pairs);
        }
    }
    stats
}

/// Cyclic-exchange local search over communication-graph *triangles*
/// (the paper's §5 future work: "allow swapping along cycles in the
/// communication graph"). Enumerates triangles `u < v < w` of `G_C`, tries
/// both rotation directions, applies strictly improving ones; repeats until
/// a full pass finds nothing (or `max_rounds`).
///
/// Runs on the fast engine only (the rotation machinery lives there).
pub fn cycle3_search(
    engine: &mut SwapEngine,
    comm: &Graph,
    rng: &mut Rng,
    max_rounds: usize,
) -> SearchStats {
    let mut triangles = comm_triangles(comm);
    cycle3_search_in(engine, &mut triangles, rng, max_rounds)
}

/// Enumerate the triangles `u < v < w` of `comm` (for each edge `(u,v)`,
/// intersect the sorted adjacencies). Exposed so sessions can cache the
/// triangle set across repetitions.
pub fn comm_triangles(comm: &Graph) -> Vec<(NodeId, NodeId, NodeId)> {
    let mut triangles: Vec<(NodeId, NodeId, NodeId)> = Vec::new();
    for u in 0..comm.n() as NodeId {
        for &v in comm.neighbors(u) {
            if v <= u {
                continue;
            }
            // sorted adjacency intersection
            let (mut i, mut j) = (0usize, 0usize);
            let nu = comm.neighbors(u);
            let nv = comm.neighbors(v);
            while i < nu.len() && j < nv.len() {
                match nu[i].cmp(&nv[j]) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        if nu[i] > v {
                            triangles.push((u, v, nu[i]));
                        }
                        i += 1;
                        j += 1;
                    }
                }
            }
        }
    }
    triangles
}

/// The search loop of [`cycle3_search`] over a caller-provided triangle set
/// (see [`comm_triangles`]); the slice is shuffled in place.
pub fn cycle3_search_in(
    engine: &mut SwapEngine,
    triangles: &mut [(NodeId, NodeId, NodeId)],
    rng: &mut Rng,
    max_rounds: usize,
) -> SearchStats {
    let mut stats = SearchStats::default();
    if triangles.is_empty() {
        return stats;
    }
    rng.shuffle(triangles);
    for _ in 0..max_rounds {
        stats.rounds += 1;
        let mut any = false;
        for &(u, v, w) in triangles.iter() {
            // both rotation directions
            stats.evaluated += 2;
            if engine.try_rotate3(u, v, w).is_some()
                || engine.try_rotate3(u, w, v).is_some()
            {
                stats.improved += 1;
                any = true;
            }
        }
        if !any {
            break;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::hierarchy::DistanceOracle;
    use crate::mapping::objective::Mapping;

    fn setup(nexp: usize, seed: u64) -> (Graph, DistanceOracle) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << nexp) / 64], vec![1, 10, 100]).unwrap();
        (g, DistanceOracle::implicit(h))
    }

    #[test]
    fn nc_pairs_d1_is_edge_set() {
        let (g, _) = setup(7, 1);
        let pairs = nc_pairs(&g, 1);
        assert_eq!(pairs.len(), g.m());
    }

    #[test]
    fn nc_pairs_nested_growth() {
        let (g, _) = setup(7, 2);
        let p1 = nc_pairs(&g, 1).len();
        let p2 = nc_pairs(&g, 2).len();
        let p3 = nc_pairs(&g, 3).len();
        assert!(p1 <= p2 && p2 <= p3, "{p1} {p2} {p3}");
        assert!(p3 > p1);
    }

    #[test]
    fn n2_reduces_objective_and_converges() {
        let (g, o) = setup(7, 3);
        let mut rng = Rng::new(4);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let before = eng.objective();
        let stats = n2_cyclic(&mut eng, g.n(), 50);
        let after = eng.objective();
        assert!(after < before, "{before} -> {after}");
        assert!(stats.rounds < 50, "did not converge");
        assert_eq!(after, eng.recompute_objective());
        // converged: no improving pair remains in the last sweep
        let final_stats = n2_cyclic(&mut eng, g.n(), 1);
        assert_eq!(final_stats.improved, 0);
    }

    #[test]
    fn np_reduces_objective() {
        let (g, o) = setup(8, 5);
        let mut rng = Rng::new(6);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let before = eng.objective();
        let h = Hierarchy::new(vec![4, 16, 4], vec![1, 10, 100]).unwrap();
        np_blocks(&mut eng, g.n(), 64, Some(&h), |e, u| e.pe_of(u), 50);
        assert!(eng.objective() < before);
        assert!(eng.gamma_invariant_holds());
    }

    #[test]
    fn nc_d1_improves_random_mapping() {
        let (g, o) = setup(8, 7);
        let mut rng = Rng::new(8);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let before = eng.objective();
        let stats = nc_neighborhood(&mut eng, &g, 1, &mut rng, u64::MAX);
        assert!(eng.objective() < before);
        assert!(stats.improved > 0);
    }

    #[test]
    fn quality_ordering_n2_best_nc1_worst() {
        // the paper's Table 2 ordering: N² >= N_10 >= N_2 >= N_1 (quality).
        // On a single random instance we just require N² <= N_1 final J.
        let (g, o) = setup(7, 9);
        let mut rng = Rng::new(10);
        let m = Mapping { sigma: rng.permutation(g.n()) };

        let mut e_n2 = SwapEngine::new(&g, &o, m.clone());
        n2_cyclic(&mut e_n2, g.n(), 100);

        let mut rng2 = Rng::new(11);
        let mut e_n1 = SwapEngine::new(&g, &o, m);
        nc_neighborhood(&mut e_n1, &g, 1, &mut rng2, u64::MAX);

        assert!(e_n2.objective() <= e_n1.objective());
    }

    #[test]
    fn np_skips_same_leaf_pairs() {
        // engine on identity mapping: processes 0..3 sit on PEs 0..3 — the
        // same leaf group of a1=4; with block_len=4 and the hierarchy given,
        // every pair in the first block is skipped.
        let (g, o) = setup(6, 12);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(g.n()));
        let h = Hierarchy::new(vec![64], vec![1]).unwrap(); // all PEs one group
        let stats = np_blocks(&mut eng, g.n(), 8, Some(&h), |e, u| e.pe_of(u), 3);
        assert_eq!(stats.evaluated, 0, "all pairs share the single leaf group");
        assert_eq!(stats.improved, 0);
    }

    #[test]
    fn rotate3_gain_matches_recompute() {
        let (g, o) = setup(7, 15);
        let mut rng = Rng::new(16);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        for _ in 0..300 {
            let n = g.n();
            let u = rng.index(n) as u32;
            let mut v = rng.index(n) as u32;
            let mut w = rng.index(n) as u32;
            if v == u {
                v = (v + 1) % n as u32;
            }
            while w == u || w == v {
                w = (w + 1) % n as u32;
            }
            let before = eng.objective();
            let gain = eng.rotate3_gain(u, v, w);
            eng.do_rotate3(u, v, w);
            assert_eq!(
                eng.objective() as i64,
                before as i64 - gain,
                "rotation ({u},{v},{w})"
            );
            assert_eq!(eng.objective(), eng.recompute_objective());
        }
        assert!(eng.gamma_invariant_holds());
        eng.mapping().validate().unwrap();
    }

    #[test]
    fn cycle3_search_improves_beyond_pair_swaps() {
        // after N_C^1 pair-swap convergence, triangle rotations may still
        // find gains (a strictly larger move class); never worsen.
        let (g, o) = setup(8, 17);
        let mut rng = Rng::new(18);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        nc_neighborhood(&mut eng, &g, 1, &mut rng, u64::MAX);
        let after_pairs = eng.objective();
        let stats = cycle3_search(&mut eng, &g, &mut rng, 50);
        assert!(eng.objective() <= after_pairs);
        assert!(stats.evaluated > 0, "rgg comm graphs contain triangles");
        assert_eq!(eng.objective(), eng.recompute_objective());
    }

    #[test]
    fn cycle3_on_triangle_free_graph_is_noop() {
        // a path graph has no triangles
        let g = crate::graph::from_edges(6, &[(0, 1, 1), (1, 2, 1), (2, 3, 1), (3, 4, 1), (4, 5, 1)]);
        let h = Hierarchy::new(vec![2, 3], vec![1, 10]).unwrap();
        let o = DistanceOracle::implicit(h);
        let mut rng = Rng::new(19);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(6));
        let stats = cycle3_search(&mut eng, &g, &mut rng, 10);
        assert_eq!(stats.evaluated, 0);
    }

    #[test]
    fn cached_pair_search_matches_uncached() {
        // nc_search_in over a precomputed pair set must follow exactly the
        // trajectory of nc_neighborhood for the same RNG (the api session's
        // scratch-reuse correctness contract)
        let (g, o) = setup(7, 30);
        let m = {
            let mut r = Rng::new(32);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut rng_a = Rng::new(31);
        let mut e1 = SwapEngine::new(&g, &o, m.clone());
        let s1 = nc_neighborhood(&mut e1, &g, 2, &mut rng_a, u64::MAX);

        let mut rng_b = Rng::new(31);
        let mut e2 = SwapEngine::new(&g, &o, m);
        let mut work = nc_pairs(&g, 2);
        let s2 = nc_search_in(&mut e2, &mut work, &mut rng_b, u64::MAX);

        assert_eq!(e1.objective(), e2.objective());
        assert_eq!(s1.evaluated, s2.evaluated);
        assert_eq!(s1.improved, s2.improved);
    }

    #[test]
    fn cached_triangle_search_matches_uncached() {
        let (g, o) = setup(7, 33);
        let m = {
            let mut r = Rng::new(34);
            Mapping { sigma: r.permutation(g.n()) }
        };
        let mut rng_a = Rng::new(35);
        let mut e1 = SwapEngine::new(&g, &o, m.clone());
        let s1 = cycle3_search(&mut e1, &g, &mut rng_a, 20);

        let mut rng_b = Rng::new(35);
        let mut e2 = SwapEngine::new(&g, &o, m);
        let mut tris = comm_triangles(&g);
        let s2 = cycle3_search_in(&mut e2, &mut tris, &mut rng_b, 20);

        assert_eq!(e1.objective(), e2.objective());
        assert_eq!(s1.evaluated, s2.evaluated);
    }

    #[test]
    fn dense_and_sparse_follow_identical_trajectory() {
        // Table 1's premise: same visit order => same swaps => same final
        // objective, only the running time differs.
        let (g, o) = setup(6, 13);
        let mut rng = Rng::new(14);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut fast = SwapEngine::new(&g, &o, m.clone());
        let mut slow = DenseEngine::new(&g, &o, m);
        let sf = n2_cyclic(&mut fast, g.n(), 10);
        let ss = n2_cyclic(&mut slow, g.n(), 10);
        assert_eq!(fast.objective(), slow.objective());
        assert_eq!(sf.improved, ss.improved);
        assert_eq!(sf.evaluated, ss.evaluated);
    }
}
