//! Multilevel V-cycle mapping: coarsen → map → uncoarsen + refine.
//!
//! The paper's constructions are single-shot; follow-up work (*High-Quality
//! Hierarchical Process Mapping*, arXiv:2001.07134; *Shared-Memory
//! Hierarchical Process Mapping*, arXiv:2504.01726) shows that refining the
//! mapping **at every level** of a coarsening hierarchy is the biggest
//! solution-quality lever for these sparse QAPs. This module implements
//! that V-cycle on top of the [`crate::mapping::refine`] framework:
//!
//! 1. **Coarsen** the communication graph with
//!    [`crate::partition::coarsen::coarsen_groups`] — heavy-edge groupings
//!    completed to *exact* clusterings, so every level shrinks by exactly
//!    the machine's fold group. In lock-step, the machine topology is
//!    **folded** through [`crate::model::topology::Topology::fold`]: each
//!    group of `g` consecutive PEs becomes one coarse PE, where `g =
//!    fold_group()` is chosen per topology (2 for even innermost structure;
//!    the whole innermost level/dimension when odd, so `3:16:k` machines
//!    coarsen in triples instead of bailing). Hierarchy folds are fully
//!    exact; grid/torus folds are representative-exact (see the topology
//!    module docs).
//! 2. **Map** the coarsest graph with *any* existing construction
//!    ([`crate::mapping::construct::initial`]) — at the coarsest level
//!    `#processes == #PEs` again, so the whole §3.1 registry applies.
//! 3. **Uncoarsen**: project level `l+1`'s mapping to level `l` (the `g`
//!    fine members of a coarse vertex take the `g` PEs of its coarse PE)
//!    and run the configured [`Refiner`] on the level-`l` graph with the
//!    level-`l` folded machine — a proper V-cycle, with per-level
//!    [`SearchStats`] surfaced as [`LevelStat`]s.
//!
//! Every projection yields a valid permutation by construction (exact
//! grouping ⇒ exactly `g` members per coarse vertex ⇒ the fine PEs
//! `g·p .. g·p+g` are each used once), and every level's refinement is
//! monotone, both enforced by `debug_assert` here and by `tests/api.rs`.

use super::algorithms::{AlgorithmSpec, Neighborhood};
use super::construct;
use super::objective::{objective, Mapping, SwapEngine};
use super::refine::{refiner_for, Refiner, SearchStats};
use crate::graph::{Graph, NodeId};
use crate::model::topology::{Hierarchy, Machine};
use crate::partition::coarsen::coarsen_groups;
use crate::partition::PartitionConfig;
use crate::util::{Rng, RunControl};

/// Knobs for building the coarsening hierarchy. Session-local by default;
/// since PR 4 the coordinator wire can carry them as optional job tokens
/// (`levels=` / `coarsen_limit=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlConfig {
    /// Maximum number of coarsening levels (the V-cycle depth).
    pub max_levels: usize,
    /// Stop coarsening once the coarse graph has at most this many
    /// vertices (clamped to ≥ 2).
    pub coarsen_limit: usize,
}

impl Default for MlConfig {
    fn default() -> Self {
        MlConfig { max_levels: 16, coarsen_limit: 64 }
    }
}

/// One coarse level of the hierarchy.
#[derive(Debug, Clone)]
pub struct MlLevel {
    /// Coarse communication graph.
    pub graph: Graph,
    /// Vertex of the next-finer graph → vertex of [`Self::graph`]
    /// (exactly [`Self::group`] fine members per coarse vertex).
    pub map: Vec<u32>,
    /// How many fine vertices/PEs merged into each coarse one at this step.
    pub group: u64,
    /// The machine folded to this level's size — it *is* this level's
    /// distance oracle (cached so repetitions share it).
    pub machine: Machine,
}

/// The coarsening hierarchy: `levels[0]` is the first coarse level,
/// `levels.last()` the coarsest. Empty when the input is already at or
/// below the limit or the machine topology cannot fold (no structure, or
/// the group does not divide the graph size).
#[derive(Debug, Clone)]
pub struct MlHierarchy {
    pub levels: Vec<MlLevel>,
}

impl MlHierarchy {
    /// Coarsen `comm` (and fold `machine` in lock-step) until the limit,
    /// the level cap, or an unfoldable machine stops it. Each step's group
    /// size comes from the machine ([`Machine::fold_group`]), so graph and
    /// machine always shrink by the same factor. Deterministic for a given
    /// `rng` state; [`crate::api::MapSession`] builds it once per job and
    /// reuses it across repetitions.
    pub fn build(comm: &Graph, machine: &Machine, cfg: &MlConfig, rng: &mut Rng) -> MlHierarchy {
        debug_assert_eq!(comm.n(), machine.n_pes());
        let limit = cfg.coarsen_limit.max(2);
        let mut levels: Vec<MlLevel> = Vec::new();
        loop {
            let step = {
                let (cur, curm) = match levels.last() {
                    Some(l) => (&l.graph, &l.machine),
                    None => (comm, machine),
                };
                if levels.len() >= cfg.max_levels || cur.n() <= limit {
                    None
                } else {
                    curm.fold_group().and_then(|g| {
                        curm.fold(g).and_then(|m| {
                            coarsen_groups(cur, g as usize, rng).map(|lvl| (lvl, g, m))
                        })
                    })
                }
            };
            match step {
                Some((lvl, group, machine)) => {
                    debug_assert_eq!(lvl.coarse.n(), machine.n_pes());
                    levels.push(MlLevel { graph: lvl.coarse, map: lvl.map, group, machine });
                }
                None => break,
            }
        }
        MlHierarchy { levels }
    }

    /// The coarsest graph/machine, or `None` when no coarsening happened
    /// (the V-cycle then degenerates to the single-level path).
    pub fn coarsest(&self) -> Option<&MlLevel> {
        self.levels.last()
    }
}

/// Per-level V-cycle statistics (coarsest level first, finest last) —
/// flattened to wire-friendly scalars so they travel in
/// [`crate::api::RepStat`] and over the service protocol.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LevelStat {
    /// Number of (coarse) processes at this level.
    pub n: usize,
    /// Level objective after projection, before this level's refinement.
    pub objective_initial: u64,
    /// Level objective after refinement.
    pub objective: u64,
    /// Gain evaluations at this level.
    pub evaluated: u64,
    /// Moves applied at this level.
    pub improved: u64,
    /// Sweeps/rounds at this level.
    pub rounds: u64,
}

/// The V-cycle's result.
#[derive(Debug, Clone)]
pub struct VcycleOutcome {
    /// Final finest-level mapping.
    pub mapping: Mapping,
    /// Finest-level objective of the *unrefined* coarse construction,
    /// projected straight down (the "after construction, before local
    /// search" baseline every report and bench compares against).
    pub objective_initial: u64,
    /// Final objective.
    pub objective: u64,
    /// Aggregate search statistics across all levels.
    pub stats: SearchStats,
    /// Per-level statistics, coarsest first (always `levels + 1` entries —
    /// the finest level is the last).
    pub levels: Vec<LevelStat>,
    /// The mapping at each level *after* refinement, coarsest first (the
    /// last entry equals [`Self::mapping`]); cheap (sizes shrink upward)
    /// and used by the validity tests.
    pub level_mappings: Vec<Mapping>,
}

/// Minimum vertices per machine-subtree block for the subtree pre-pass —
/// below this the per-block setup outweighs any refinement it could find.
const SUBTREE_MIN_BLOCK: usize = 16;

/// Refine the top-level machine-subtree blocks of `sigma` independently,
/// before the level's full refinement pass.
///
/// The hierarchy distance between PEs in *different* top-level blocks is
/// the constant outermost distance wherever the two vertices sit inside
/// their blocks (the ultrametric property), so a move that stays inside one
/// block leaves every cross-block term of J unchanged: the blocks are truly
/// independent subproblems — each an induced subgraph mapped onto the
/// sub-hierarchy with the outermost level dropped — and refining them
/// concurrently is exact, not heuristic.
///
/// Runs at every thread count — scoped worker threads at `threads > 1`,
/// inline otherwise — with bit-identical results either way: per-block RNG
/// seeds are fixed up front (`salt + block`), blocks share no state, and
/// results are stitched back in block order. This is what keeps `ml:` runs
/// reproducible across `--threads` settings (tested in `tests/api.rs`).
///
/// Skipped (returning zero stats, identically at every thread count) for
/// machines without hierarchy structure, single-level hierarchies (all
/// intra-block distances equal, so intra-block moves cannot change J),
/// fewer than two blocks, or blocks under [`SUBTREE_MIN_BLOCK`].
fn subtree_refine(
    graph: &Graph,
    oracle: &Machine,
    sigma: &mut [u32],
    spec: &AlgorithmSpec,
    threads: usize,
    salt: u64,
    ctrl: &RunControl,
) -> SearchStats {
    let mut out = SearchStats::default();
    if matches!(spec.neighborhood, Neighborhood::None) {
        return out;
    }
    let Some(h) = oracle.hier() else { return out };
    if h.s.len() < 2 {
        return out;
    }
    let k = *h.s.last().expect("non-empty hierarchy") as usize;
    let n = graph.n();
    if k < 2 || n % k != 0 {
        return out;
    }
    let bs = n / k;
    if bs < SUBTREE_MIN_BLOCK {
        return out;
    }
    let Ok(sub) =
        Hierarchy::new(h.s[..h.s.len() - 1].to_vec(), h.d[..h.d.len() - 1].to_vec())
    else {
        return out;
    };
    let sub_machine = Machine::Hier(sub);
    debug_assert_eq!(sub_machine.n_pes(), bs);

    // partition the vertices by the top-level block their PE lives in
    // (hierarchy PEs number depth-first, so block b is the contiguous PE
    // range b·bs .. (b+1)·bs)
    let mut members: Vec<Vec<NodeId>> = vec![Vec::with_capacity(bs); k];
    for (u, &pe) in sigma.iter().enumerate() {
        members[pe as usize / bs].push(u as NodeId);
    }
    // σ is a bijection, so every block holds exactly bs vertices
    debug_assert!(members.iter().all(|m| m.len() == bs));
    let mut local = vec![0u32; n];
    for verts in &members {
        for (i, &u) in verts.iter().enumerate() {
            local[u as usize] = i as u32;
        }
    }

    // induced per-block instances, relabeled 0..bs in member id order
    struct Block {
        verts: Vec<NodeId>,
        graph: Graph,
        start: Mapping,
    }
    let blocks: Vec<Block> = members
        .into_iter()
        .enumerate()
        .map(|(b, verts)| {
            let base = (b * bs) as u32;
            let mut edges = Vec::new();
            let mut start = vec![0u32; bs];
            for &u in &verts {
                start[local[u as usize] as usize] = sigma[u as usize] - base;
                for (v, w) in graph.edges(u) {
                    if v > u && sigma[v as usize] as usize / bs == b {
                        edges.push((local[u as usize], local[v as usize], w));
                    }
                }
            }
            Block {
                verts,
                graph: crate::graph::from_edges(bs, &edges),
                start: Mapping { sigma: start },
            }
        })
        .collect();

    // refine every block with a fresh refiner and its own fixed-seed RNG;
    // the per-block computation depends only on the block's own instance,
    // so inline and worker execution produce identical mappings
    let run_block = |b: usize, blk: &Block| -> (Mapping, SearchStats) {
        let mut refiner = refiner_for(spec.neighborhood, spec.max_sweeps, &sub_machine);
        refiner.set_control(ctrl);
        let mut rng = Rng::new(salt.wrapping_add(b as u64));
        let mut eng = SwapEngine::new(&blk.graph, &sub_machine, blk.start.clone());
        let j0 = eng.objective();
        let s = refiner.refine(&mut eng, &blk.graph, &mut rng);
        debug_assert!(eng.objective() <= j0, "block {b}: subtree refinement worsened");
        (eng.mapping(), s)
    };
    let mut results: Vec<Option<(Mapping, SearchStats)>> = (0..k).map(|_| None).collect();
    if threads > 1 {
        let chunk = k.div_ceil(threads.min(k));
        std::thread::scope(|sc| {
            for (ci, (blks, outs)) in
                blocks.chunks(chunk).zip(results.chunks_mut(chunk)).enumerate()
            {
                let run_block = &run_block;
                sc.spawn(move || {
                    for (j, blk) in blks.iter().enumerate() {
                        outs[j] = Some(run_block(ci * chunk + j, blk));
                    }
                });
            }
        });
    } else {
        for (b, blk) in blocks.iter().enumerate() {
            results[b] = Some(run_block(b, blk));
        }
    }

    // stitch the refined blocks back in block order
    for (b, (blk, res)) in blocks.iter().zip(results).enumerate() {
        let (mapping, s) = res.expect("every block was refined");
        let base = (b * bs) as u32;
        for (i, &u) in blk.verts.iter().enumerate() {
            sigma[u as usize] = base + mapping.sigma[i];
        }
        out.absorb(&s);
    }
    out
}

/// Project a coarse mapping one level down: the `group` fine members of
/// coarse vertex `c` (in id order) take PEs `group·σ_c(c) + 0 ..
/// group·σ_c(c) + group`. A bijection in ⇒ a bijection out.
pub fn project(map: &[u32], coarse_sigma: &[u32], group: u32) -> Vec<u32> {
    let mut taken = vec![0u32; coarse_sigma.len()];
    let mut sigma = vec![0u32; map.len()];
    for (v, &c) in map.iter().enumerate() {
        let slot = taken[c as usize];
        taken[c as usize] += 1;
        debug_assert!(slot < group, "coarse vertex {c} has more than {group} members");
        sigma[v] = group * coarse_sigma[c as usize] + slot;
    }
    sigma
}

/// Run the uncoarsening half of the V-cycle: starting from a mapping of the
/// coarsest graph, refine, project down, refine, … until the finest level.
///
/// `refiners` must hold `ml.levels.len() + 1` refiners, **coarsest first**
/// (the last refines the finest graph against `fine_oracle`); keeping them
/// alive across calls reuses their pair/triangle scratch per level. `gamma`
/// is the shared Γ-buffer threaded through every level's [`SwapEngine`].
///
/// Each level first runs the machine-subtree pre-pass ([`subtree_refine`]
/// — independent top-level blocks, on worker threads when `threads > 1`,
/// bit-identical at every thread count) and then the level's full refiner;
/// `spec` configures the per-block refiners of the pre-pass. A level's
/// [`LevelStat`] aggregates both phases; its `objective_initial` is still
/// measured right after projection, before either phase.
///
/// `ctrl` is the anytime stop token: once a deadline or cancellation
/// fires (inside a refiner or between levels), the remaining levels skip
/// both refinement phases and only *project* the best-so-far mapping down
/// to the finest graph — projection preserves validity, so a stopped
/// V-cycle always returns a usable mapping, flagged via
/// [`SearchStats::stopped`]. A disarmed token changes nothing: the salt
/// draw stays unconditional and every check is one branch.
#[allow(clippy::too_many_arguments)]
pub fn vcycle_refine(
    comm: &Graph,
    fine_oracle: &Machine,
    ml: &MlHierarchy,
    coarse: Mapping,
    refiners: &mut [Box<dyn Refiner>],
    rng: &mut Rng,
    gamma: &mut Vec<u64>,
    spec: &AlgorithmSpec,
    threads: usize,
    ctrl: &RunControl,
) -> VcycleOutcome {
    let depth = ml.levels.len();
    assert_eq!(refiners.len(), depth + 1, "one refiner per level plus the finest");
    let mut stats = SearchStats::default();
    let mut levels_out = Vec::with_capacity(depth + 1);
    let mut level_mappings = Vec::with_capacity(depth + 1);
    let armed = ctrl.armed();
    // the construction projected down *without* refinement, for the
    // report's objective_initial baseline
    let mut raw = coarse.sigma.clone();
    let mut sigma = coarse.sigma;
    for i in 0..=depth {
        let (graph, oracle) = if i < depth {
            let lvl = &ml.levels[depth - 1 - i];
            (&lvl.graph, &lvl.machine)
        } else {
            (comm, fine_oracle)
        };
        debug_assert_eq!(graph.n(), sigma.len());
        // per-level salt for the subtree pre-pass, drawn unconditionally
        // so the RNG stream is identical at every thread count
        let salt = rng.next_u64();
        let mut start = Mapping { sigma: std::mem::take(&mut sigma) };
        let j0 = objective(graph, oracle, &start);
        if armed && stats.stopped.is_none() {
            if let Some(r) = ctrl.stop_reason() {
                stats.stopped = Some(r);
            }
        }
        let (s, j1, mapping) = if stats.stopped.is_some() {
            // already stopped: this level only carries the best-so-far
            // mapping through (projection continues below)
            (SearchStats::default(), j0, start)
        } else {
            let mut s =
                subtree_refine(graph, oracle, &mut start.sigma, spec, threads, salt, ctrl);
            let buf = std::mem::take(gamma);
            let mut eng = SwapEngine::with_gamma_buf(graph, oracle, start, buf);
            debug_assert!(eng.objective() <= j0, "level {i}: subtree pre-pass worsened");
            refiners[i].set_control(ctrl);
            let sf = refiners[i].refine(&mut eng, graph, rng);
            s.absorb(&sf);
            let j1 = eng.objective();
            debug_assert!(j1 <= j0, "level {i}: refinement worsened {j0} -> {j1}");
            let (mapping, buf) = eng.into_parts();
            *gamma = buf;
            (s, j1, mapping)
        };
        debug_assert!(mapping.validate().is_ok());
        stats.absorb(&s);
        levels_out.push(LevelStat {
            n: graph.n(),
            objective_initial: j0,
            objective: j1,
            evaluated: s.evaluated,
            improved: s.improved,
            rounds: s.rounds,
        });
        if i < depth {
            let lvl = &ml.levels[depth - 1 - i];
            sigma = project(&lvl.map, &mapping.sigma, lvl.group as u32);
            raw = project(&lvl.map, &raw, lvl.group as u32);
        }
        level_mappings.push(mapping);
    }
    let mapping = level_mappings.last().expect("loop ran at least once").clone();
    let objective_initial = objective(comm, fine_oracle, &Mapping { sigma: raw });
    let objective = levels_out.last().expect("at least the finest level").objective;
    VcycleOutcome {
        mapping,
        objective_initial,
        objective,
        stats,
        levels: levels_out,
        level_mappings,
    }
}

/// Convenience entry point: build the hierarchy, construct the coarsest
/// mapping with `spec_construction`, and run [`vcycle_refine`] with one
/// fresh refiner per level. [`crate::api::MapSession`] uses the split
/// pieces instead so the hierarchy and refiner scratch persist across
/// repetitions; this function serves tests, examples and one-shot callers.
#[allow(clippy::too_many_arguments)]
pub fn vcycle(
    comm: &Graph,
    machine: &Machine,
    fine_oracle: &Machine,
    spec: &super::algorithms::AlgorithmSpec,
    cfg: &MlConfig,
    part_cfg: &PartitionConfig,
    hierarchy_rng: &mut Rng,
    rng: &mut Rng,
) -> (MlHierarchy, VcycleOutcome) {
    let ml = MlHierarchy::build(comm, machine, cfg, hierarchy_rng);
    let mut refiners = level_refiners(&ml, machine, spec);
    let coarse = match ml.coarsest() {
        Some(l) => {
            construct::initial(&l.graph, &l.machine, &l.machine, spec.construction, part_cfg, rng)
        }
        None => construct::initial(comm, machine, fine_oracle, spec.construction, part_cfg, rng),
    };
    let mut gamma = Vec::new();
    let outcome = vcycle_refine(
        comm,
        fine_oracle,
        &ml,
        coarse,
        &mut refiners,
        rng,
        &mut gamma,
        spec,
        1,
        &RunControl::unlimited(),
    );
    (ml, outcome)
}

/// One refiner per level (coarsest first, finest last), each bound to its
/// level's folded machine so the `N_p` skip rule stays correct.
pub fn level_refiners(
    ml: &MlHierarchy,
    machine: &Machine,
    spec: &super::algorithms::AlgorithmSpec,
) -> Vec<Box<dyn Refiner>> {
    let depth = ml.levels.len();
    (0..=depth)
        .map(|i| {
            let m = if i < depth { &ml.levels[depth - 1 - i].machine } else { machine };
            super::refine::refiner_for(spec.neighborhood, spec.max_sweeps, m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::algorithms::AlgorithmSpec;
    use crate::model::topology::Hierarchy;

    fn setup(n: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(n, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (n / 64) as u64], vec![1, 10, 100]).unwrap();
        (g, Machine::Hier(h))
    }

    fn run_vcycle(
        g: &Graph,
        m: &Machine,
        spec: &AlgorithmSpec,
        cfg: &MlConfig,
        hierarchy_seed: u64,
        rep_seed: u64,
    ) -> (MlHierarchy, VcycleOutcome) {
        let mut hrng = Rng::new(hierarchy_seed);
        let mut rng = Rng::new(rep_seed);
        let part = PartitionConfig::perfectly_balanced();
        vcycle(g, m, m, spec, cfg, &part, &mut hrng, &mut rng)
    }

    #[test]
    fn hierarchy_builds_and_halves() {
        let (g, m) = setup(256, 1);
        let mut rng = Rng::new(2);
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        assert_eq!(ml.levels.len(), 3); // 256 -> 128 -> 64 -> 32
        let mut expect = 128;
        for lvl in &ml.levels {
            assert_eq!(lvl.group, 2);
            assert_eq!(lvl.graph.n(), expect);
            assert_eq!(lvl.machine.n_pes(), expect);
            assert_eq!(lvl.graph.validate(), Ok(()));
            expect /= 2;
        }
        // total node weight is the fine vertex count at every level
        assert_eq!(ml.coarsest().unwrap().graph.total_node_weight(), 256);
    }

    #[test]
    fn odd_fanout_machine_folds_in_triples() {
        // 3:16:2 = 96 PEs: the first fold consumes the whole innermost
        // level (group 3), later folds halve the 16 — the non-halving case
        // the ROADMAP asked for
        let mut rng = Rng::new(3);
        let g = random_geometric_graph(96, &mut rng);
        let m = Machine::Hier(Hierarchy::new(vec![3, 16, 2], vec![1, 10, 100]).unwrap());
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 8 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        let sizes: Vec<usize> = ml.levels.iter().map(|l| l.graph.n()).collect();
        let groups: Vec<u64> = ml.levels.iter().map(|l| l.group).collect();
        assert_eq!(sizes, vec![32, 16, 8]); // 96 -(÷3)-> 32 -(÷2)-> 16 -> 8
        assert_eq!(groups, vec![3, 2, 2]);
        for lvl in &ml.levels {
            assert_eq!(lvl.machine.n_pes(), lvl.graph.n());
        }
        assert_eq!(ml.levels[0].machine.hier().unwrap().s, vec![16, 2]);
    }

    #[test]
    fn grid_machine_coarsens_with_folded_links() {
        let mut rng = Rng::new(4);
        let g = random_geometric_graph(64, &mut rng);
        let m = Machine::parse("grid:8x8@1").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 8 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        let sizes: Vec<usize> = ml.levels.iter().map(|l| l.graph.n()).collect();
        assert_eq!(sizes, vec![32, 16, 8]);
        for lvl in &ml.levels {
            assert_eq!(lvl.machine.kind(), "grid");
            assert_eq!(lvl.machine.n_pes(), lvl.graph.n());
        }
    }

    #[test]
    fn projection_is_a_bijection() {
        let map = vec![0, 2, 1, 2, 0, 1]; // 6 fine -> 3 coarse, 2 members each
        let sigma = project(&map, &[2, 0, 1], 2);
        let m = Mapping { sigma };
        m.validate().unwrap();
        // members in id order: vertex 0 (first of cluster 0 at PE 2) -> 4
        assert_eq!(m.sigma, vec![4, 2, 0, 3, 5, 1]);
        // and for a triple grouping
        let map3 = vec![0, 1, 0, 1, 1, 0]; // 6 fine -> 2 coarse, 3 members
        let sigma3 = project(&map3, &[1, 0], 3);
        let m3 = Mapping { sigma: sigma3 };
        m3.validate().unwrap();
        assert_eq!(m3.sigma, vec![3, 0, 4, 1, 2, 5]);
    }

    #[test]
    fn vcycle_valid_monotone_and_improves() {
        let (g, m) = setup(256, 3);
        let spec = AlgorithmSpec::parse("topdown+Nc3").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let (ml, out) = run_vcycle(&g, &m, &spec, &cfg, 7, 8);
        assert_eq!(out.levels.len(), ml.levels.len() + 1);
        assert_eq!(out.level_mappings.len(), out.levels.len());
        for (i, (stat, mp)) in out.levels.iter().zip(&out.level_mappings).enumerate() {
            mp.validate().unwrap_or_else(|e| panic!("level {i}: {e}"));
            assert!(stat.objective <= stat.objective_initial, "level {i} worsened");
            assert_eq!(mp.n(), stat.n);
        }
        assert_eq!(out.mapping.sigma, out.level_mappings.last().unwrap().sigma);
        assert_eq!(out.objective, objective(&g, &m, &out.mapping));
        assert!(out.objective <= out.objective_initial);
        assert!(out.stats.evaluated > 0);
    }

    #[test]
    fn vcycle_runs_on_odd_fanout_and_grid_machines() {
        let mut rng = Rng::new(9);
        let g = random_geometric_graph(96, &mut rng);
        let spec = AlgorithmSpec::parse("mm+Nc2").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 8 };
        for spec_str in ["hier:3:16:2@1:10:100", "grid:12x8@1", "torus:4x4x6@1"] {
            let m = Machine::parse(spec_str).unwrap();
            assert_eq!(m.n_pes(), 96, "{spec_str}");
            let (ml, out) = run_vcycle(&g, &m, &spec, &cfg, 17, 18);
            assert!(!ml.levels.is_empty(), "{spec_str}: no coarsening happened");
            out.mapping.validate().unwrap();
            assert_eq!(out.objective, objective(&g, &m, &out.mapping), "{spec_str}");
            assert!(out.objective <= out.objective_initial, "{spec_str}");
        }
    }

    #[test]
    fn vcycle_deterministic_for_fixed_seeds() {
        let (g, m) = setup(128, 4);
        let spec = AlgorithmSpec::parse("topdown+Nc2").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 16 };
        let a = run_vcycle(&g, &m, &spec, &cfg, 11, 12).1;
        let b = run_vcycle(&g, &m, &spec, &cfg, 11, 12).1;
        assert_eq!(a.mapping.sigma, b.mapping.sigma);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.levels, b.levels);
    }

    #[test]
    fn vcycle_degenerates_without_coarsening() {
        // coarsen_limit above n: no levels, the V-cycle is construct+refine
        let (g, m) = setup(128, 5);
        let spec = AlgorithmSpec::parse("mm+Nc1").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 4096 };
        let (ml, out) = run_vcycle(&g, &m, &spec, &cfg, 13, 14);
        assert!(ml.levels.is_empty());
        assert_eq!(out.levels.len(), 1);
        out.mapping.validate().unwrap();
        // an explicit (structureless) machine also degenerates cleanly
        let e = Machine::explicit(&m);
        let cfg2 = MlConfig { max_levels: 8, coarsen_limit: 16 };
        let (ml2, out2) = run_vcycle(&g, &e, &spec, &cfg2, 13, 14);
        assert!(ml2.levels.is_empty());
        out2.mapping.validate().unwrap();
    }

    #[test]
    fn subtree_pre_pass_is_thread_invariant() {
        // the V-cycle's coarse-parallel contract: vcycle_refine at
        // threads ∈ {1, 2, 4} produces identical outcomes — per-block
        // seeds are fixed up front and the blocks are independent, so
        // worker scheduling cannot leak into the result
        let (g, m) = setup(256, 21);
        let spec = AlgorithmSpec::parse("topdown+Nc3").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let mut hrng = Rng::new(22);
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut hrng);
        let part = PartitionConfig::perfectly_balanced();
        let coarse = {
            let l = ml.coarsest().expect("256 coarsens below 32");
            let mut crng = Rng::new(23);
            construct::initial(&l.graph, &l.machine, &l.machine, spec.construction, &part, &mut crng)
        };
        let mut base: Option<VcycleOutcome> = None;
        for t in [1usize, 2, 4] {
            let mut refiners = level_refiners(&ml, &m, &spec);
            let mut rng = Rng::new(24);
            let mut gamma = Vec::new();
            let out = vcycle_refine(
                &g,
                &m,
                &ml,
                coarse.clone(),
                &mut refiners,
                &mut rng,
                &mut gamma,
                &spec,
                t,
                &RunControl::unlimited(),
            );
            out.mapping.validate().unwrap();
            match &base {
                None => base = Some(out),
                Some(b) => {
                    assert_eq!(out.mapping.sigma, b.mapping.sigma, "threads={t}");
                    assert_eq!(out.objective, b.objective, "threads={t}");
                    assert_eq!(out.levels, b.levels, "threads={t}");
                }
            }
        }
        let b = base.unwrap();
        assert!(b.objective <= b.objective_initial);
    }

    #[test]
    fn vcycle_not_worse_than_projection_baseline() {
        // the whole point: refined-at-every-level beats (or ties) the raw
        // projected construction
        let (g, m) = setup(256, 6);
        let spec = AlgorithmSpec::parse("topdown+Nc5").unwrap();
        let cfg = MlConfig::default();
        let (_, out) = run_vcycle(&g, &m, &spec, &cfg, 15, 16);
        assert!(
            out.objective < out.objective_initial,
            "{} vs {}",
            out.objective,
            out.objective_initial
        );
    }
}
