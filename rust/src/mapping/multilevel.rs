//! Multilevel V-cycle mapping: coarsen → map → uncoarsen + refine.
//!
//! The paper's constructions are single-shot; follow-up work (*High-Quality
//! Hierarchical Process Mapping*, arXiv:2001.07134; *Shared-Memory
//! Hierarchical Process Mapping*, arXiv:2504.01726) shows that refining the
//! mapping **at every level** of a coarsening hierarchy is the biggest
//! solution-quality lever for these sparse QAPs. This module implements
//! that V-cycle on top of the [`crate::mapping::refine`] framework:
//!
//! 1. **Coarsen** the communication graph in lock-step with the machine.
//!    Each step's shape comes from the machine as a
//!    [`crate::model::topology::FoldPlan`]: uniform machines fold every
//!    `g` consecutive PEs into one coarse PE (`FoldPlan::Uniform`, the
//!    graph side matched by
//!    [`crate::partition::coarsen::coarsen_groups`] — `g = 2` for even
//!    innermost structure, the whole innermost level/dimension when odd,
//!    so `3:16:k` machines coarsen in triples instead of bailing); a
//!    [`crate::model::topology::SubsystemTree`] with coprime leaf sizes
//!    folds whole *unequal* leaves (`FoldPlan::Blocks`, matched by
//!    [`crate::partition::coarsen::coarsen_blocks`]). Hierarchy and tree
//!    folds are fully exact; grid/torus folds are representative-exact
//!    (see the topology module docs).
//! 2. **Map** the coarsest graph with *any* existing construction
//!    ([`crate::mapping::construct::initial`]) — at the coarsest level
//!    `#processes == #PEs` again, so the whole §3.1 registry applies.
//! 3. **Uncoarsen**: project level `l+1`'s mapping to level `l` by
//!    sequential allocation (each coarse vertex's members, in id order,
//!    take a consecutive fine-PE range laid out in coarse-PE order — the
//!    classic `g·p + slot` rule in the uniform case) and run the
//!    configured [`Refiner`] on the level-`l` graph with the level-`l`
//!    folded machine — a proper V-cycle, with per-level [`SearchStats`]
//!    surfaced as [`LevelStat`]s.
//!
//! Every projection yields a valid permutation by construction (exact
//! clustering ⇒ cluster sizes sum to the fine size ⇒ consecutive ranges
//! tile the fine PEs), and every level's refinement is monotone, both
//! enforced by `debug_assert` here and by `tests/api.rs`.

use super::algorithms::{AlgorithmSpec, Neighborhood};
use super::construct;
use super::objective::{objective, Mapping, SwapEngine};
use super::refine::{refiner_for, Refiner, SearchStats};
use crate::graph::{Graph, NodeId};
use crate::model::topology::{FoldPlan, Machine};
use crate::partition::coarsen::{coarsen_blocks, coarsen_groups};
use crate::partition::PartitionConfig;
use crate::util::{Rng, RunControl};

/// Knobs for building the coarsening hierarchy. Session-local by default;
/// since PR 4 the coordinator wire can carry them as optional job tokens
/// (`levels=` / `coarsen_limit=`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlConfig {
    /// Maximum number of coarsening levels (the V-cycle depth).
    pub max_levels: usize,
    /// Stop coarsening once the coarse graph has at most this many
    /// vertices (clamped to ≥ 2).
    pub coarsen_limit: usize,
}

impl Default for MlConfig {
    fn default() -> Self {
        MlConfig { max_levels: 16, coarsen_limit: 64 }
    }
}

/// One coarse level of the hierarchy.
#[derive(Debug, Clone)]
pub struct MlLevel {
    /// Coarse communication graph.
    pub graph: Graph,
    /// Vertex of the next-finer graph → vertex of [`Self::graph`] (cluster
    /// sizes follow [`Self::plan`]).
    pub map: Vec<u32>,
    /// How the fine vertices/PEs merged into coarse ones at this step: a
    /// uniform group size, or per-block sizes for non-uniform tree folds.
    pub plan: FoldPlan,
    /// The machine folded to this level's size — it *is* this level's
    /// distance oracle (cached so repetitions share it).
    pub machine: Machine,
}

/// The coarsening hierarchy: `levels[0]` is the first coarse level,
/// `levels.last()` the coarsest. Empty when the input is already at or
/// below the limit or the machine topology cannot fold (no structure, or
/// the group does not divide the graph size).
#[derive(Debug, Clone)]
pub struct MlHierarchy {
    pub levels: Vec<MlLevel>,
}

impl MlHierarchy {
    /// Coarsen `comm` (and fold `machine` in lock-step) until the limit,
    /// the level cap, or an unfoldable machine stops it. Each step's shape
    /// comes from the machine ([`Machine::fold_plan`]), so graph and
    /// machine always shrink together — by one group size on uniform
    /// machines, by per-leaf block sizes on non-uniform subsystem trees.
    /// Deterministic for a given `rng` state; [`crate::api::MapSession`]
    /// builds it once per job and reuses it across repetitions.
    pub fn build(comm: &Graph, machine: &Machine, cfg: &MlConfig, rng: &mut Rng) -> MlHierarchy {
        debug_assert_eq!(comm.n(), machine.n_pes());
        let limit = cfg.coarsen_limit.max(2);
        let mut levels: Vec<MlLevel> = Vec::new();
        loop {
            let step = {
                let (cur, curm) = match levels.last() {
                    Some(l) => (&l.graph, &l.machine),
                    None => (comm, machine),
                };
                if levels.len() >= cfg.max_levels || cur.n() <= limit {
                    None
                } else {
                    curm.fold_plan().and_then(|plan| {
                        curm.fold_by(&plan).and_then(|m| {
                            let lvl = match &plan {
                                FoldPlan::Uniform(g) => coarsen_groups(cur, *g as usize, rng),
                                FoldPlan::Blocks(sizes) => coarsen_blocks(cur, sizes, rng),
                            };
                            lvl.map(|lvl| (lvl, plan, m))
                        })
                    })
                }
            };
            match step {
                Some((lvl, plan, machine)) => {
                    debug_assert_eq!(lvl.coarse.n(), machine.n_pes());
                    debug_assert_eq!(lvl.coarse.n(), plan.coarse_pes(lvl.map.len()));
                    levels.push(MlLevel { graph: lvl.coarse, map: lvl.map, plan, machine });
                }
                None => break,
            }
        }
        MlHierarchy { levels }
    }

    /// The coarsest graph/machine, or `None` when no coarsening happened
    /// (the V-cycle then degenerates to the single-level path).
    pub fn coarsest(&self) -> Option<&MlLevel> {
        self.levels.last()
    }
}

/// Per-level V-cycle statistics (coarsest level first, finest last) —
/// flattened to wire-friendly scalars so they travel in
/// [`crate::api::RepStat`] and over the service protocol.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LevelStat {
    /// Number of (coarse) processes at this level.
    pub n: usize,
    /// Level objective after projection, before this level's refinement.
    pub objective_initial: u64,
    /// Level objective after refinement.
    pub objective: u64,
    /// Gain evaluations at this level.
    pub evaluated: u64,
    /// Moves applied at this level.
    pub improved: u64,
    /// Sweeps/rounds at this level.
    pub rounds: u64,
}

/// The V-cycle's result.
#[derive(Debug, Clone)]
pub struct VcycleOutcome {
    /// Final finest-level mapping.
    pub mapping: Mapping,
    /// Finest-level objective of the *unrefined* coarse construction,
    /// projected straight down (the "after construction, before local
    /// search" baseline every report and bench compares against).
    pub objective_initial: u64,
    /// Final objective.
    pub objective: u64,
    /// Aggregate search statistics across all levels.
    pub stats: SearchStats,
    /// Per-level statistics, coarsest first (always `levels + 1` entries —
    /// the finest level is the last).
    pub levels: Vec<LevelStat>,
    /// The mapping at each level *after* refinement, coarsest first (the
    /// last entry equals [`Self::mapping`]); cheap (sizes shrink upward)
    /// and used by the validity tests.
    pub level_mappings: Vec<Mapping>,
}

/// Minimum vertices per machine-subtree block for the subtree pre-pass —
/// below this the per-block setup outweighs any refinement it could find.
const SUBTREE_MIN_BLOCK: usize = 16;

/// Refine the top-level machine-subtree blocks of `sigma` independently,
/// before the level's full refinement pass.
///
/// The machine distance between PEs in *different* top-level blocks is the
/// constant outermost distance wherever the two vertices sit inside their
/// blocks (the ultrametric property), so a move that stays inside one
/// block leaves every cross-block term of J unchanged: the blocks are truly
/// independent subproblems — each an induced subgraph mapped onto its own
/// sub-machine ([`Machine::top_blocks`]: the outermost hierarchy level
/// dropped, or a subsystem tree's root child re-based to PE 0) — and
/// refining them concurrently is exact, not heuristic. On non-uniform
/// trees the blocks are generally *unequal*; per-block seeds stay fixed so
/// results remain thread-invariant.
///
/// Runs at every thread count — scoped worker threads at `threads > 1`,
/// inline otherwise — with bit-identical results either way: per-block RNG
/// seeds are fixed up front (`salt + block`), blocks share no state, and
/// results are stitched back in block order. This is what keeps `ml:` runs
/// reproducible across `--threads` settings (tested in `tests/api.rs`).
///
/// Skipped (returning zero stats, identically at every thread count) for
/// machines without top-level block structure (lattices, matrices,
/// single-level hierarchies — all intra-block distances equal there) or
/// when every block is under [`SUBTREE_MIN_BLOCK`]; individual blocks
/// below the threshold are carried through unrefined.
fn subtree_refine(
    graph: &Graph,
    oracle: &Machine,
    sigma: &mut [u32],
    spec: &AlgorithmSpec,
    threads: usize,
    salt: u64,
    ctrl: &RunControl,
) -> SearchStats {
    let mut out = SearchStats::default();
    if matches!(spec.neighborhood, Neighborhood::None) {
        return out;
    }
    let Some(top) = oracle.top_blocks() else { return out };
    let n = graph.n();
    let k = top.len();
    let sizes: Vec<usize> = top.iter().map(|(_, m)| m.n_pes()).collect();
    if sizes.iter().sum::<usize>() != n {
        return out;
    }
    if sizes.iter().all(|&bs| bs < SUBTREE_MIN_BLOCK) {
        return out;
    }

    // partition the vertices by the top-level block their PE lives in
    // (subsystem PEs number depth-first, so block b is the contiguous PE
    // range starting at its pe_start)
    let mut block_of = vec![0u32; n];
    for (b, (start, m)) in top.iter().enumerate() {
        block_of[*start as usize..*start as usize + m.n_pes()].fill(b as u32);
    }
    let mut members: Vec<Vec<NodeId>> =
        sizes.iter().map(|&bs| Vec::with_capacity(bs)).collect();
    for (u, &pe) in sigma.iter().enumerate() {
        members[block_of[pe as usize] as usize].push(u as NodeId);
    }
    // σ is a bijection, so every block holds exactly its PE count
    debug_assert!(members.iter().zip(&sizes).all(|(m, &bs)| m.len() == bs));
    let mut local = vec![0u32; n];
    for verts in &members {
        for (i, &u) in verts.iter().enumerate() {
            local[u as usize] = i as u32;
        }
    }

    // induced per-block instances, relabeled 0..bs in member id order
    struct Block {
        verts: Vec<NodeId>,
        graph: Graph,
        start: Mapping,
        base: u32,
        machine: Machine,
    }
    let blocks: Vec<Block> = members
        .into_iter()
        .zip(top)
        .enumerate()
        .map(|(b, (verts, (base, machine)))| {
            let bs = machine.n_pes();
            let mut edges = Vec::new();
            let mut start = vec![0u32; bs];
            for &u in &verts {
                start[local[u as usize] as usize] = sigma[u as usize] - base;
                for (v, w) in graph.edges(u) {
                    if v > u && block_of[sigma[v as usize] as usize] == b as u32 {
                        edges.push((local[u as usize], local[v as usize], w));
                    }
                }
            }
            Block {
                verts,
                graph: crate::graph::from_edges(bs, &edges),
                start: Mapping { sigma: start },
                base,
                machine,
            }
        })
        .collect();

    // refine every block with a fresh refiner and its own fixed-seed RNG;
    // the per-block computation depends only on the block's own instance,
    // so inline and worker execution produce identical mappings
    let run_block = |b: usize, blk: &Block| -> (Mapping, SearchStats) {
        if blk.graph.n() < SUBTREE_MIN_BLOCK {
            // too small to pay the per-block setup — carried through as-is
            return (blk.start.clone(), SearchStats::default());
        }
        let mut refiner = refiner_for(spec.neighborhood, spec.max_sweeps, &blk.machine);
        refiner.set_control(ctrl);
        let mut rng = Rng::new(salt.wrapping_add(b as u64));
        let mut eng = SwapEngine::new(&blk.graph, &blk.machine, blk.start.clone());
        let j0 = eng.objective();
        let s = refiner.refine(&mut eng, &blk.graph, &mut rng);
        debug_assert!(eng.objective() <= j0, "block {b}: subtree refinement worsened");
        (eng.mapping(), s)
    };
    let mut results: Vec<Option<(Mapping, SearchStats)>> = (0..k).map(|_| None).collect();
    if threads > 1 {
        let chunk = k.div_ceil(threads.min(k));
        std::thread::scope(|sc| {
            for (ci, (blks, outs)) in
                blocks.chunks(chunk).zip(results.chunks_mut(chunk)).enumerate()
            {
                let run_block = &run_block;
                sc.spawn(move || {
                    for (j, blk) in blks.iter().enumerate() {
                        outs[j] = Some(run_block(ci * chunk + j, blk));
                    }
                });
            }
        });
    } else {
        for (b, blk) in blocks.iter().enumerate() {
            results[b] = Some(run_block(b, blk));
        }
    }

    // stitch the refined blocks back in block order
    for (blk, res) in blocks.iter().zip(results) {
        let (mapping, s) = res.expect("every block was refined");
        for (i, &u) in blk.verts.iter().enumerate() {
            sigma[u as usize] = blk.base + mapping.sigma[i];
        }
        out.absorb(&s);
    }
    out
}

/// Project a coarse mapping one level down by *sequential allocation*:
/// invert `coarse_sigma` to find the cluster at each coarse PE, lay the
/// clusters out over the fine PEs in coarse-PE order (cluster sizes are
/// derived from `map`), and hand each cluster's members, in id order, its
/// consecutive fine-PE range. A bijection in ⇒ a bijection out, for any
/// cluster-size profile. On uniform levels (every cluster of size `g`)
/// this reduces bit-for-bit to the classic `g·σ_c(c) + slot` rule.
///
/// Non-uniform caveat: a cluster's size need not match the machine-block
/// size at its assigned coarse position, so the projected σ can shear
/// across leaf boundaries — the coarse level is then an approximation the
/// per-level refinement absorbs (the machine *fold* itself stays exact).
pub fn project(map: &[u32], coarse_sigma: &[u32]) -> Vec<u32> {
    let k = coarse_sigma.len();
    let mut size = vec![0u32; k];
    for &c in map {
        size[c as usize] += 1;
    }
    // cluster owning each coarse PE (coarse_sigma is a bijection)
    let mut cluster_at = vec![0u32; k];
    for (c, &p) in coarse_sigma.iter().enumerate() {
        cluster_at[p as usize] = c as u32;
    }
    // next free fine PE per cluster, allocated in coarse-PE order
    let mut next = vec![0u32; k];
    let mut acc = 0u32;
    for &c in &cluster_at {
        next[c as usize] = acc;
        acc += size[c as usize];
    }
    debug_assert_eq!(acc as usize, map.len(), "cluster sizes must tile the fine PEs");
    map.iter()
        .map(|&c| {
            let pe = next[c as usize];
            next[c as usize] += 1;
            pe
        })
        .collect()
}

/// Run the uncoarsening half of the V-cycle: starting from a mapping of the
/// coarsest graph, refine, project down, refine, … until the finest level.
///
/// `refiners` must hold `ml.levels.len() + 1` refiners, **coarsest first**
/// (the last refines the finest graph against `fine_oracle`); keeping them
/// alive across calls reuses their pair/triangle scratch per level. `gamma`
/// is the shared Γ-buffer threaded through every level's [`SwapEngine`].
///
/// Each level first runs the machine-subtree pre-pass ([`subtree_refine`]
/// — independent top-level blocks, on worker threads when `threads > 1`,
/// bit-identical at every thread count) and then the level's full refiner;
/// `spec` configures the per-block refiners of the pre-pass. A level's
/// [`LevelStat`] aggregates both phases; its `objective_initial` is still
/// measured right after projection, before either phase.
///
/// `ctrl` is the anytime stop token: once a deadline or cancellation
/// fires (inside a refiner or between levels), the remaining levels skip
/// both refinement phases and only *project* the best-so-far mapping down
/// to the finest graph — projection preserves validity, so a stopped
/// V-cycle always returns a usable mapping, flagged via
/// [`SearchStats::stopped`]. A disarmed token changes nothing: the salt
/// draw stays unconditional and every check is one branch.
#[allow(clippy::too_many_arguments)]
pub fn vcycle_refine(
    comm: &Graph,
    fine_oracle: &Machine,
    ml: &MlHierarchy,
    coarse: Mapping,
    refiners: &mut [Box<dyn Refiner>],
    rng: &mut Rng,
    gamma: &mut Vec<u64>,
    spec: &AlgorithmSpec,
    threads: usize,
    ctrl: &RunControl,
) -> VcycleOutcome {
    let depth = ml.levels.len();
    assert_eq!(refiners.len(), depth + 1, "one refiner per level plus the finest");
    let mut stats = SearchStats::default();
    let mut levels_out = Vec::with_capacity(depth + 1);
    let mut level_mappings = Vec::with_capacity(depth + 1);
    let armed = ctrl.armed();
    // the construction projected down *without* refinement, for the
    // report's objective_initial baseline
    let mut raw = coarse.sigma.clone();
    let mut sigma = coarse.sigma;
    for i in 0..=depth {
        let (graph, oracle) = if i < depth {
            let lvl = &ml.levels[depth - 1 - i];
            (&lvl.graph, &lvl.machine)
        } else {
            (comm, fine_oracle)
        };
        debug_assert_eq!(graph.n(), sigma.len());
        // per-level salt for the subtree pre-pass, drawn unconditionally
        // so the RNG stream is identical at every thread count
        let salt = rng.next_u64();
        let mut start = Mapping { sigma: std::mem::take(&mut sigma) };
        let j0 = objective(graph, oracle, &start);
        if armed && stats.stopped.is_none() {
            if let Some(r) = ctrl.stop_reason() {
                stats.stopped = Some(r);
            }
        }
        let (s, j1, mapping) = if stats.stopped.is_some() {
            // already stopped: this level only carries the best-so-far
            // mapping through (projection continues below)
            (SearchStats::default(), j0, start)
        } else {
            let mut s =
                subtree_refine(graph, oracle, &mut start.sigma, spec, threads, salt, ctrl);
            let buf = std::mem::take(gamma);
            let mut eng = SwapEngine::with_gamma_buf(graph, oracle, start, buf);
            debug_assert!(eng.objective() <= j0, "level {i}: subtree pre-pass worsened");
            refiners[i].set_control(ctrl);
            let sf = refiners[i].refine(&mut eng, graph, rng);
            s.absorb(&sf);
            let j1 = eng.objective();
            debug_assert!(j1 <= j0, "level {i}: refinement worsened {j0} -> {j1}");
            let (mapping, buf) = eng.into_parts();
            *gamma = buf;
            (s, j1, mapping)
        };
        debug_assert!(mapping.validate().is_ok());
        stats.absorb(&s);
        levels_out.push(LevelStat {
            n: graph.n(),
            objective_initial: j0,
            objective: j1,
            evaluated: s.evaluated,
            improved: s.improved,
            rounds: s.rounds,
        });
        if i < depth {
            let lvl = &ml.levels[depth - 1 - i];
            sigma = project(&lvl.map, &mapping.sigma);
            raw = project(&lvl.map, &raw);
        }
        level_mappings.push(mapping);
    }
    let mapping = level_mappings.last().expect("loop ran at least once").clone();
    let objective_initial = objective(comm, fine_oracle, &Mapping { sigma: raw });
    let objective = levels_out.last().expect("at least the finest level").objective;
    VcycleOutcome {
        mapping,
        objective_initial,
        objective,
        stats,
        levels: levels_out,
        level_mappings,
    }
}

/// Convenience entry point: build the hierarchy, construct the coarsest
/// mapping with `spec_construction`, and run [`vcycle_refine`] with one
/// fresh refiner per level. [`crate::api::MapSession`] uses the split
/// pieces instead so the hierarchy and refiner scratch persist across
/// repetitions; this function serves tests, examples and one-shot callers.
#[allow(clippy::too_many_arguments)]
pub fn vcycle(
    comm: &Graph,
    machine: &Machine,
    fine_oracle: &Machine,
    spec: &super::algorithms::AlgorithmSpec,
    cfg: &MlConfig,
    part_cfg: &PartitionConfig,
    hierarchy_rng: &mut Rng,
    rng: &mut Rng,
) -> (MlHierarchy, VcycleOutcome) {
    let ml = MlHierarchy::build(comm, machine, cfg, hierarchy_rng);
    let mut refiners = level_refiners(&ml, machine, spec);
    let coarse = match ml.coarsest() {
        Some(l) => {
            construct::initial(&l.graph, &l.machine, &l.machine, spec.construction, part_cfg, rng)
        }
        None => construct::initial(comm, machine, fine_oracle, spec.construction, part_cfg, rng),
    };
    let mut gamma = Vec::new();
    let outcome = vcycle_refine(
        comm,
        fine_oracle,
        &ml,
        coarse,
        &mut refiners,
        rng,
        &mut gamma,
        spec,
        1,
        &RunControl::unlimited(),
    );
    (ml, outcome)
}

/// One refiner per level (coarsest first, finest last), each bound to its
/// level's folded machine so the `N_p` skip rule stays correct.
pub fn level_refiners(
    ml: &MlHierarchy,
    machine: &Machine,
    spec: &super::algorithms::AlgorithmSpec,
) -> Vec<Box<dyn Refiner>> {
    let depth = ml.levels.len();
    (0..=depth)
        .map(|i| {
            let m = if i < depth { &ml.levels[depth - 1 - i].machine } else { machine };
            super::refine::refiner_for(spec.neighborhood, spec.max_sweeps, m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::algorithms::AlgorithmSpec;
    use crate::model::topology::Hierarchy;

    fn setup(n: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(n, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (n / 64) as u64], vec![1, 10, 100]).unwrap();
        (g, Machine::Hier(h))
    }

    fn run_vcycle(
        g: &Graph,
        m: &Machine,
        spec: &AlgorithmSpec,
        cfg: &MlConfig,
        hierarchy_seed: u64,
        rep_seed: u64,
    ) -> (MlHierarchy, VcycleOutcome) {
        let mut hrng = Rng::new(hierarchy_seed);
        let mut rng = Rng::new(rep_seed);
        let part = PartitionConfig::perfectly_balanced();
        vcycle(g, m, m, spec, cfg, &part, &mut hrng, &mut rng)
    }

    #[test]
    fn hierarchy_builds_and_halves() {
        let (g, m) = setup(256, 1);
        let mut rng = Rng::new(2);
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        assert_eq!(ml.levels.len(), 3); // 256 -> 128 -> 64 -> 32
        let mut expect = 128;
        for lvl in &ml.levels {
            assert_eq!(lvl.plan, FoldPlan::Uniform(2));
            assert_eq!(lvl.graph.n(), expect);
            assert_eq!(lvl.machine.n_pes(), expect);
            assert_eq!(lvl.graph.validate(), Ok(()));
            expect /= 2;
        }
        // total node weight is the fine vertex count at every level
        assert_eq!(ml.coarsest().unwrap().graph.total_node_weight(), 256);
    }

    #[test]
    fn odd_fanout_machine_folds_in_triples() {
        // 3:16:2 = 96 PEs: the first fold consumes the whole innermost
        // level (group 3), later folds halve the 16 — the non-halving case
        // the ROADMAP asked for
        let mut rng = Rng::new(3);
        let g = random_geometric_graph(96, &mut rng);
        let m = Machine::Hier(Hierarchy::new(vec![3, 16, 2], vec![1, 10, 100]).unwrap());
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 8 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        let sizes: Vec<usize> = ml.levels.iter().map(|l| l.graph.n()).collect();
        let plans: Vec<FoldPlan> = ml.levels.iter().map(|l| l.plan.clone()).collect();
        assert_eq!(sizes, vec![32, 16, 8]); // 96 -(÷3)-> 32 -(÷2)-> 16 -> 8
        assert_eq!(
            plans,
            vec![FoldPlan::Uniform(3), FoldPlan::Uniform(2), FoldPlan::Uniform(2)]
        );
        for lvl in &ml.levels {
            assert_eq!(lvl.machine.n_pes(), lvl.graph.n());
        }
        assert_eq!(ml.levels[0].machine.hier().unwrap().s, vec![16, 2]);
    }

    #[test]
    fn fattree_builds_with_unequal_block_plan() {
        // fattree:3,5:2 = 16 PEs in pods of 3 and 5 leaves (2 PEs each).
        // The gcd fold halves the uniform leaves first (16 -> 8, leaves
        // become [3, 5]); with coprime leaf sizes the next step folds whole
        // unequal leaves (8 -> 2) — both plan kinds in one hierarchy.
        let mut rng = Rng::new(31);
        let g = random_geometric_graph(16, &mut rng);
        let m = Machine::parse("fattree:3,5:2").unwrap();
        assert_eq!(m.n_pes(), 16);
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 2 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        let sizes: Vec<usize> = ml.levels.iter().map(|l| l.graph.n()).collect();
        let plans: Vec<FoldPlan> = ml.levels.iter().map(|l| l.plan.clone()).collect();
        assert_eq!(sizes, vec![8, 2]);
        assert_eq!(plans, vec![FoldPlan::Uniform(2), FoldPlan::Blocks(vec![3, 5])]);
        for lvl in &ml.levels {
            assert_eq!(lvl.machine.n_pes(), lvl.graph.n());
            assert_eq!(lvl.machine.kind(), "tree");
            assert_eq!(lvl.graph.validate(), Ok(()));
        }
        // the folded 8-PE machine keeps the unequal pod structure
        assert_eq!(ml.levels[0].machine.tree().unwrap().leaf_sizes(), vec![3, 5]);
        assert_eq!(ml.coarsest().unwrap().graph.total_node_weight(), 16);
    }

    #[test]
    fn grid_machine_coarsens_with_folded_links() {
        let mut rng = Rng::new(4);
        let g = random_geometric_graph(64, &mut rng);
        let m = Machine::parse("grid:8x8@1").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 8 };
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut rng);
        let sizes: Vec<usize> = ml.levels.iter().map(|l| l.graph.n()).collect();
        assert_eq!(sizes, vec![32, 16, 8]);
        for lvl in &ml.levels {
            assert_eq!(lvl.machine.kind(), "grid");
            assert_eq!(lvl.machine.n_pes(), lvl.graph.n());
        }
    }

    #[test]
    fn projection_is_a_bijection() {
        let map = vec![0, 2, 1, 2, 0, 1]; // 6 fine -> 3 coarse, 2 members each
        let sigma = project(&map, &[2, 0, 1]);
        let m = Mapping { sigma };
        m.validate().unwrap();
        // uniform case: bit-identical to the classic g·σ_c(c) + slot rule —
        // vertex 0 (first of cluster 0 at PE 2) -> 2·2 + 0 = 4
        assert_eq!(m.sigma, vec![4, 2, 0, 3, 5, 1]);
        // and for a triple grouping
        let map3 = vec![0, 1, 0, 1, 1, 0]; // 6 fine -> 2 coarse, 3 members
        let sigma3 = project(&map3, &[1, 0]);
        let m3 = Mapping { sigma: sigma3 };
        m3.validate().unwrap();
        assert_eq!(m3.sigma, vec![3, 0, 4, 1, 2, 5]);
    }

    #[test]
    fn projection_handles_unequal_clusters() {
        // clusters of size 1, 3, 2; coarse σ = [1, 2, 0]: sequential
        // allocation lays cluster 2 (coarse PE 0) at fine 0..2, cluster 0
        // (coarse PE 1) at fine 2..3, cluster 1 (coarse PE 2) at fine 3..6
        let map = vec![1, 2, 0, 1, 2, 1];
        let sigma = project(&map, &[1, 2, 0]);
        Mapping { sigma: sigma.clone() }.validate().unwrap();
        assert_eq!(sigma, vec![3, 0, 2, 4, 1, 5]);
        // permuting the coarse mapping permutes the ranges, still bijective
        let sigma2 = project(&map, &[0, 1, 2]);
        Mapping { sigma: sigma2.clone() }.validate().unwrap();
        assert_eq!(sigma2, vec![1, 4, 0, 2, 5, 3]);
    }

    #[test]
    fn vcycle_valid_monotone_and_improves() {
        let (g, m) = setup(256, 3);
        let spec = AlgorithmSpec::parse("topdown+Nc3").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let (ml, out) = run_vcycle(&g, &m, &spec, &cfg, 7, 8);
        assert_eq!(out.levels.len(), ml.levels.len() + 1);
        assert_eq!(out.level_mappings.len(), out.levels.len());
        for (i, (stat, mp)) in out.levels.iter().zip(&out.level_mappings).enumerate() {
            mp.validate().unwrap_or_else(|e| panic!("level {i}: {e}"));
            assert!(stat.objective <= stat.objective_initial, "level {i} worsened");
            assert_eq!(mp.n(), stat.n);
        }
        assert_eq!(out.mapping.sigma, out.level_mappings.last().unwrap().sigma);
        assert_eq!(out.objective, objective(&g, &m, &out.mapping));
        assert!(out.objective <= out.objective_initial);
        assert!(out.stats.evaluated > 0);
    }

    #[test]
    fn vcycle_runs_on_odd_fanout_and_grid_machines() {
        let mut rng = Rng::new(9);
        let g = random_geometric_graph(96, &mut rng);
        let spec = AlgorithmSpec::parse("mm+Nc2").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 8 };
        for spec_str in [
            "hier:3:16:2@1:10:100",
            "grid:12x8@1",
            "torus:4x4x6@1",
            "fattree:4,8:8",
            "dragonfly:3,3,2:12@1:10:100",
        ] {
            let m = Machine::parse(spec_str).unwrap();
            assert_eq!(m.n_pes(), 96, "{spec_str}");
            let (ml, out) = run_vcycle(&g, &m, &spec, &cfg, 17, 18);
            assert!(!ml.levels.is_empty(), "{spec_str}: no coarsening happened");
            out.mapping.validate().unwrap();
            assert_eq!(out.objective, objective(&g, &m, &out.mapping), "{spec_str}");
            assert!(out.objective <= out.objective_initial, "{spec_str}");
        }
    }

    #[test]
    fn vcycle_deterministic_for_fixed_seeds() {
        let (g, m) = setup(128, 4);
        let spec = AlgorithmSpec::parse("topdown+Nc2").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 16 };
        let a = run_vcycle(&g, &m, &spec, &cfg, 11, 12).1;
        let b = run_vcycle(&g, &m, &spec, &cfg, 11, 12).1;
        assert_eq!(a.mapping.sigma, b.mapping.sigma);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.levels, b.levels);
    }

    #[test]
    fn vcycle_degenerates_without_coarsening() {
        // coarsen_limit above n: no levels, the V-cycle is construct+refine
        let (g, m) = setup(128, 5);
        let spec = AlgorithmSpec::parse("mm+Nc1").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 4096 };
        let (ml, out) = run_vcycle(&g, &m, &spec, &cfg, 13, 14);
        assert!(ml.levels.is_empty());
        assert_eq!(out.levels.len(), 1);
        out.mapping.validate().unwrap();
        // an explicit (structureless) machine also degenerates cleanly
        let e = Machine::explicit(&m);
        let cfg2 = MlConfig { max_levels: 8, coarsen_limit: 16 };
        let (ml2, out2) = run_vcycle(&g, &e, &spec, &cfg2, 13, 14);
        assert!(ml2.levels.is_empty());
        out2.mapping.validate().unwrap();
    }

    #[test]
    fn subtree_pre_pass_is_thread_invariant() {
        // the V-cycle's coarse-parallel contract: vcycle_refine at
        // threads ∈ {1, 2, 4} produces identical outcomes — per-block
        // seeds are fixed up front and the blocks are independent, so
        // worker scheduling cannot leak into the result
        let (g, m) = setup(256, 21);
        let spec = AlgorithmSpec::parse("topdown+Nc3").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let mut hrng = Rng::new(22);
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut hrng);
        let part = PartitionConfig::perfectly_balanced();
        let coarse = {
            let l = ml.coarsest().expect("256 coarsens below 32");
            let mut crng = Rng::new(23);
            construct::initial(&l.graph, &l.machine, &l.machine, spec.construction, &part, &mut crng)
        };
        let mut base: Option<VcycleOutcome> = None;
        for t in [1usize, 2, 4] {
            let mut refiners = level_refiners(&ml, &m, &spec);
            let mut rng = Rng::new(24);
            let mut gamma = Vec::new();
            let out = vcycle_refine(
                &g,
                &m,
                &ml,
                coarse.clone(),
                &mut refiners,
                &mut rng,
                &mut gamma,
                &spec,
                t,
                &RunControl::unlimited(),
            );
            out.mapping.validate().unwrap();
            match &base {
                None => base = Some(out),
                Some(b) => {
                    assert_eq!(out.mapping.sigma, b.mapping.sigma, "threads={t}");
                    assert_eq!(out.objective, b.objective, "threads={t}");
                    assert_eq!(out.levels, b.levels, "threads={t}");
                }
            }
        }
        let b = base.unwrap();
        assert!(b.objective <= b.objective_initial);
    }

    #[test]
    fn fattree_subtree_pre_pass_is_thread_invariant() {
        // same contract as above, but the top-level blocks are *unequal*
        // (pods of 48 and 80 PEs): per-block seeds stay fixed by block
        // index, so worker scheduling still cannot leak into the result
        let mut grng = Rng::new(41);
        let g = random_geometric_graph(128, &mut grng);
        let m = Machine::parse("fattree:3,5:16").unwrap();
        assert_eq!(m.n_pes(), 128);
        let spec = AlgorithmSpec::parse("topdown+Nc3").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let mut hrng = Rng::new(42);
        let ml = MlHierarchy::build(&g, &m, &cfg, &mut hrng);
        assert!(!ml.levels.is_empty(), "fat-tree must coarsen");
        let part = PartitionConfig::perfectly_balanced();
        let coarse = {
            let l = ml.coarsest().unwrap();
            let mut crng = Rng::new(43);
            construct::initial(&l.graph, &l.machine, &l.machine, spec.construction, &part, &mut crng)
        };
        let mut base: Option<VcycleOutcome> = None;
        for t in [1usize, 2, 4] {
            let mut refiners = level_refiners(&ml, &m, &spec);
            let mut rng = Rng::new(44);
            let mut gamma = Vec::new();
            let out = vcycle_refine(
                &g,
                &m,
                &ml,
                coarse.clone(),
                &mut refiners,
                &mut rng,
                &mut gamma,
                &spec,
                t,
                &RunControl::unlimited(),
            );
            out.mapping.validate().unwrap();
            match &base {
                None => base = Some(out),
                Some(b) => {
                    assert_eq!(out.mapping.sigma, b.mapping.sigma, "threads={t}");
                    assert_eq!(out.objective, b.objective, "threads={t}");
                    assert_eq!(out.levels, b.levels, "threads={t}");
                }
            }
        }
        let b = base.unwrap();
        assert!(b.objective <= b.objective_initial);
    }

    #[test]
    fn vcycle_not_worse_than_projection_baseline() {
        // the whole point: refined-at-every-level beats (or ties) the raw
        // projected construction
        let (g, m) = setup(256, 6);
        let spec = AlgorithmSpec::parse("topdown+Nc5").unwrap();
        let cfg = MlConfig::default();
        let (_, out) = run_vcycle(&g, &m, &spec, &cfg, 15, 16);
        assert!(
            out.objective < out.objective_initial,
            "{} vs {}",
            out.objective,
            out.objective_initial
        );
    }
}
