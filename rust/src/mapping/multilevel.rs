//! Multilevel V-cycle mapping: coarsen → map → uncoarsen + refine.
//!
//! The paper's constructions are single-shot; follow-up work (*High-Quality
//! Hierarchical Process Mapping*, arXiv:2001.07134; *Shared-Memory
//! Hierarchical Process Mapping*, arXiv:2504.01726) shows that refining the
//! mapping **at every level** of a coarsening hierarchy is the biggest
//! solution-quality lever for these sparse QAPs. This module implements
//! that V-cycle on top of the [`crate::mapping::refine`] framework:
//!
//! 1. **Coarsen** the communication graph with
//!    [`crate::partition::coarsen::coarsen_halving`] — heavy-edge matchings
//!    completed to *perfect* matchings, so every level halves exactly.
//!    In lock-step, the machine hierarchy is **folded**: halving the
//!    innermost fan-out `a_1` merges PE pairs `{2p, 2p+1}` into one coarse
//!    PE, and the ultrametric distances stay exact (every subsystem size is
//!    divided by two, so `D_coarse(p, q) = D(2p+b, 2q+b')` for all
//!    `b, b' ∈ {0,1}` whenever `p ≠ q`).
//! 2. **Map** the coarsest graph with *any* existing construction
//!    ([`crate::mapping::construct::initial`]) — at the coarsest level
//!    `#processes == #PEs` again, so the whole §3.1 registry applies.
//! 3. **Uncoarsen**: project level `l+1`'s mapping to level `l` (the two
//!    fine members of a coarse vertex take the two PEs of its coarse PE)
//!    and run the configured [`Refiner`] on the level-`l` graph with the
//!    level-`l` folded hierarchy — a proper V-cycle, with per-level
//!    [`SearchStats`] surfaced as [`LevelStat`]s.
//!
//! Every projection yields a valid permutation by construction (perfect
//! matching ⇒ exactly two members per coarse vertex ⇒ the fine PEs `2p`
//! and `2p+1` are each used once), and every level's refinement is
//! monotone, both enforced by `debug_assert` here and by `tests/api.rs`.

use super::construct;
use super::hierarchy::{DistanceOracle, Hierarchy};
use super::objective::{objective, Mapping, SwapEngine};
use super::refine::{Refiner, SearchStats};
use crate::graph::Graph;
use crate::partition::coarsen::coarsen_halving;
use crate::partition::PartitionConfig;
use crate::util::Rng;

/// Knobs for building the coarsening hierarchy (session-local, like
/// [`PartitionConfig`] — they do not cross the service wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlConfig {
    /// Maximum number of halving levels (the V-cycle depth).
    pub max_levels: usize,
    /// Stop coarsening once the coarse graph has at most this many
    /// vertices (clamped to ≥ 2).
    pub coarsen_limit: usize,
}

impl Default for MlConfig {
    fn default() -> Self {
        MlConfig { max_levels: 16, coarsen_limit: 64 }
    }
}

/// One coarse level of the hierarchy.
#[derive(Debug, Clone)]
pub struct MlLevel {
    /// Coarse communication graph (`n / 2^level` vertices).
    pub graph: Graph,
    /// Vertex of the next-finer graph → vertex of [`Self::graph`]
    /// (exactly two fine members per coarse vertex).
    pub map: Vec<u32>,
    /// The machine hierarchy folded to this level's size.
    pub hierarchy: Hierarchy,
    /// Implicit distance oracle over [`Self::hierarchy`] (cached so
    /// repetitions share it).
    pub oracle: DistanceOracle,
}

/// The coarsening hierarchy: `levels[0]` is the first coarse level (half
/// the input size), `levels.last()` the coarsest. Empty when the input is
/// already at or below the limit, the size is odd, or the machine hierarchy
/// cannot fold (odd innermost fan-out).
#[derive(Debug, Clone)]
pub struct MlHierarchy {
    pub levels: Vec<MlLevel>,
}

/// Fold the machine hierarchy one halving step: `a_1 /= 2`, dropping the
/// level entirely when it reaches 1 (its distance `d_1` becomes
/// unobservable — coarse PEs are single units). `None` when `a_1` is odd
/// (the ultrametric would not survive) or the machine is a single PE.
pub fn halve_hierarchy(h: &Hierarchy) -> Option<Hierarchy> {
    let mut s = h.s.clone();
    let mut d = h.d.clone();
    if s[0] % 2 != 0 {
        return None;
    }
    s[0] /= 2;
    if s[0] == 1 && s.len() > 1 {
        s.remove(0);
        d.remove(0);
    }
    Hierarchy::new(s, d).ok()
}

impl MlHierarchy {
    /// Coarsen `comm` (and fold `machine` in lock-step) until the limit,
    /// the level cap, an odd size, or an unfoldable machine stops it.
    /// Deterministic for a given `rng` state; [`crate::api::MapSession`]
    /// builds it once per job and reuses it across repetitions.
    pub fn build(comm: &Graph, machine: &Hierarchy, cfg: &MlConfig, rng: &mut Rng) -> MlHierarchy {
        debug_assert_eq!(comm.n(), machine.n_pes());
        let limit = cfg.coarsen_limit.max(2);
        let mut levels: Vec<MlLevel> = Vec::new();
        loop {
            let step = {
                let (cur, curh) = match levels.last() {
                    Some(l) => (&l.graph, &l.hierarchy),
                    None => (comm, machine),
                };
                if levels.len() >= cfg.max_levels || cur.n() <= limit {
                    None
                } else {
                    halve_hierarchy(curh)
                        .and_then(|h| coarsen_halving(cur, rng).map(|lvl| (lvl, h)))
                }
            };
            match step {
                Some((lvl, hierarchy)) => {
                    let oracle = DistanceOracle::implicit(hierarchy.clone());
                    levels.push(MlLevel { graph: lvl.coarse, map: lvl.map, hierarchy, oracle });
                }
                None => break,
            }
        }
        MlHierarchy { levels }
    }

    /// The coarsest graph/hierarchy/oracle, or `None` when no coarsening
    /// happened (the V-cycle then degenerates to the single-level path).
    pub fn coarsest(&self) -> Option<&MlLevel> {
        self.levels.last()
    }
}

/// Per-level V-cycle statistics (coarsest level first, finest last) —
/// flattened to wire-friendly scalars so they travel in
/// [`crate::api::RepStat`] and over the service protocol.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LevelStat {
    /// Number of (coarse) processes at this level.
    pub n: usize,
    /// Level objective after projection, before this level's refinement.
    pub objective_initial: u64,
    /// Level objective after refinement.
    pub objective: u64,
    /// Gain evaluations at this level.
    pub evaluated: u64,
    /// Moves applied at this level.
    pub improved: u64,
    /// Sweeps/rounds at this level.
    pub rounds: u64,
}

/// The V-cycle's result.
#[derive(Debug, Clone)]
pub struct VcycleOutcome {
    /// Final finest-level mapping.
    pub mapping: Mapping,
    /// Finest-level objective of the *unrefined* coarse construction,
    /// projected straight down (the "after construction, before local
    /// search" baseline every report and bench compares against).
    pub objective_initial: u64,
    /// Final objective.
    pub objective: u64,
    /// Aggregate search statistics across all levels.
    pub stats: SearchStats,
    /// Per-level statistics, coarsest first (always `levels + 1` entries —
    /// the finest level is the last).
    pub levels: Vec<LevelStat>,
    /// The mapping at each level *after* refinement, coarsest first (the
    /// last entry equals [`Self::mapping`]); cheap (sizes halve upward) and
    /// used by the validity tests.
    pub level_mappings: Vec<Mapping>,
}

/// Project a coarse mapping one level down: the two fine members of coarse
/// vertex `c` (in id order) take PEs `2·σ_c(c)` and `2·σ_c(c) + 1`. A
/// bijection in ⇒ a bijection out.
pub fn project(map: &[u32], coarse_sigma: &[u32]) -> Vec<u32> {
    let mut taken = vec![false; coarse_sigma.len()];
    let mut sigma = vec![0u32; map.len()];
    for (v, &c) in map.iter().enumerate() {
        let first = !taken[c as usize];
        taken[c as usize] = true;
        sigma[v] = 2 * coarse_sigma[c as usize] + if first { 0 } else { 1 };
    }
    sigma
}

/// Run the uncoarsening half of the V-cycle: starting from a mapping of the
/// coarsest graph, refine, project down, refine, … until the finest level.
///
/// `refiners` must hold `ml.levels.len() + 1` refiners, **coarsest first**
/// (the last refines the finest graph against `fine_oracle`); keeping them
/// alive across calls reuses their pair/triangle scratch per level. `gamma`
/// is the shared Γ-buffer threaded through every level's [`SwapEngine`].
pub fn vcycle_refine(
    comm: &Graph,
    fine_oracle: &DistanceOracle,
    ml: &MlHierarchy,
    coarse: Mapping,
    refiners: &mut [Box<dyn Refiner>],
    rng: &mut Rng,
    gamma: &mut Vec<u64>,
) -> VcycleOutcome {
    let depth = ml.levels.len();
    assert_eq!(refiners.len(), depth + 1, "one refiner per level plus the finest");
    let mut stats = SearchStats::default();
    let mut levels_out = Vec::with_capacity(depth + 1);
    let mut level_mappings = Vec::with_capacity(depth + 1);
    // the construction projected down *without* refinement, for the
    // report's objective_initial baseline
    let mut raw = coarse.sigma.clone();
    let mut sigma = coarse.sigma;
    for i in 0..=depth {
        let (graph, oracle) = if i < depth {
            let lvl = &ml.levels[depth - 1 - i];
            (&lvl.graph, &lvl.oracle)
        } else {
            (comm, fine_oracle)
        };
        debug_assert_eq!(graph.n(), sigma.len());
        let buf = std::mem::take(gamma);
        let start = Mapping { sigma: std::mem::take(&mut sigma) };
        let mut eng = SwapEngine::with_gamma_buf(graph, oracle, start, buf);
        let j0 = eng.objective();
        let s = refiners[i].refine(&mut eng, graph, rng);
        let j1 = eng.objective();
        debug_assert!(j1 <= j0, "level {i}: refinement worsened {j0} -> {j1}");
        let (mapping, buf) = eng.into_parts();
        *gamma = buf;
        debug_assert!(mapping.validate().is_ok());
        stats.absorb(&s);
        levels_out.push(LevelStat {
            n: graph.n(),
            objective_initial: j0,
            objective: j1,
            evaluated: s.evaluated,
            improved: s.improved,
            rounds: s.rounds,
        });
        if i < depth {
            let map = &ml.levels[depth - 1 - i].map;
            sigma = project(map, &mapping.sigma);
            raw = project(map, &raw);
        }
        level_mappings.push(mapping);
    }
    let mapping = level_mappings.last().expect("loop ran at least once").clone();
    let objective_initial = objective(comm, fine_oracle, &Mapping { sigma: raw });
    let objective = levels_out.last().expect("at least the finest level").objective;
    VcycleOutcome {
        mapping,
        objective_initial,
        objective,
        stats,
        levels: levels_out,
        level_mappings,
    }
}

/// Convenience entry point: build the hierarchy, construct the coarsest
/// mapping with `spec_construction`, and run [`vcycle_refine`] with one
/// fresh refiner per level. [`crate::api::MapSession`] uses the split
/// pieces instead so the hierarchy and refiner scratch persist across
/// repetitions; this function serves tests, examples and one-shot callers.
#[allow(clippy::too_many_arguments)]
pub fn vcycle(
    comm: &Graph,
    machine: &Hierarchy,
    fine_oracle: &DistanceOracle,
    spec: &super::algorithms::AlgorithmSpec,
    cfg: &MlConfig,
    part_cfg: &PartitionConfig,
    hierarchy_rng: &mut Rng,
    rng: &mut Rng,
) -> (MlHierarchy, VcycleOutcome) {
    let ml = MlHierarchy::build(comm, machine, cfg, hierarchy_rng);
    let mut refiners = level_refiners(&ml, machine, spec);
    let coarse = match ml.coarsest() {
        Some(l) => {
            construct::initial(&l.graph, &l.hierarchy, &l.oracle, spec.construction, part_cfg, rng)
        }
        None => construct::initial(comm, machine, fine_oracle, spec.construction, part_cfg, rng),
    };
    let mut gamma = Vec::new();
    let outcome = vcycle_refine(comm, fine_oracle, &ml, coarse, &mut refiners, rng, &mut gamma);
    (ml, outcome)
}

/// One refiner per level (coarsest first, finest last), each bound to its
/// level's folded hierarchy so the `N_p` skip rule stays correct.
pub fn level_refiners(
    ml: &MlHierarchy,
    machine: &Hierarchy,
    spec: &super::algorithms::AlgorithmSpec,
) -> Vec<Box<dyn Refiner>> {
    let depth = ml.levels.len();
    (0..=depth)
        .map(|i| {
            let h = if i < depth { &ml.levels[depth - 1 - i].hierarchy } else { machine };
            super::refine::refiner_for(spec.neighborhood, spec.max_sweeps, h)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::algorithms::AlgorithmSpec;

    fn setup(n: usize, seed: u64) -> (Graph, Hierarchy, DistanceOracle) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(n, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (n / 64) as u64], vec![1, 10, 100]).unwrap();
        let o = DistanceOracle::implicit(h.clone());
        (g, h, o)
    }

    fn run_vcycle(
        g: &Graph,
        h: &Hierarchy,
        o: &DistanceOracle,
        spec: &AlgorithmSpec,
        cfg: &MlConfig,
        hierarchy_seed: u64,
        rep_seed: u64,
    ) -> (MlHierarchy, VcycleOutcome) {
        let mut hrng = Rng::new(hierarchy_seed);
        let mut rng = Rng::new(rep_seed);
        let part = PartitionConfig::perfectly_balanced();
        vcycle(g, h, o, spec, cfg, &part, &mut hrng, &mut rng)
    }

    #[test]
    fn halve_hierarchy_folds_innermost() {
        let h = Hierarchy::new(vec![4, 16, 2], vec![1, 10, 100]).unwrap();
        let h1 = halve_hierarchy(&h).unwrap();
        assert_eq!(h1.s, vec![2, 16, 2]);
        assert_eq!(h1.d, vec![1, 10, 100]);
        let h2 = halve_hierarchy(&h1).unwrap();
        assert_eq!(h2.s, vec![16, 2]);
        assert_eq!(h2.d, vec![10, 100]);
        assert_eq!(h2.n_pes(), 32);
        // odd innermost fan-out cannot fold
        assert!(halve_hierarchy(&Hierarchy::new(vec![3, 4], vec![1, 10]).unwrap()).is_none());
        // flat hierarchies fold down to a single PE and then stop
        let flat = Hierarchy::new(vec![2], vec![1]).unwrap();
        let f1 = halve_hierarchy(&flat).unwrap();
        assert_eq!(f1.n_pes(), 1);
        assert!(halve_hierarchy(&f1).is_none());
    }

    #[test]
    fn folded_distances_are_exact() {
        // D_coarse(p, q) must equal D(2p+b, 2q+b') for p != q, any b, b'
        let h = Hierarchy::new(vec![4, 16, 2], vec![1, 10, 100]).unwrap();
        let hc = halve_hierarchy(&h).unwrap();
        for p in 0..hc.n_pes() as u32 {
            for q in 0..hc.n_pes() as u32 {
                if p == q {
                    continue;
                }
                for b in 0..2u32 {
                    for b2 in 0..2u32 {
                        assert_eq!(
                            hc.distance(p, q),
                            h.distance(2 * p + b, 2 * q + b2),
                            "({p},{q}) fold mismatch"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn hierarchy_builds_and_halves() {
        let (g, h, _) = setup(256, 1);
        let mut rng = Rng::new(2);
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let ml = MlHierarchy::build(&g, &h, &cfg, &mut rng);
        assert_eq!(ml.levels.len(), 3); // 256 -> 128 -> 64 -> 32
        let mut expect = 128;
        for lvl in &ml.levels {
            assert_eq!(lvl.graph.n(), expect);
            assert_eq!(lvl.hierarchy.n_pes(), expect);
            assert_eq!(lvl.graph.validate(), Ok(()));
            expect /= 2;
        }
        // total node weight is the fine vertex count at every level
        assert_eq!(ml.coarsest().unwrap().graph.total_node_weight(), 256);
    }

    #[test]
    fn projection_is_a_bijection() {
        let map = vec![0, 2, 1, 2, 0, 1]; // 6 fine -> 3 coarse, 2 members each
        let sigma = project(&map, &[2, 0, 1]);
        let m = Mapping { sigma };
        m.validate().unwrap();
        // members in id order: vertex 0 (first of cluster 0 at PE 2) -> 4
        assert_eq!(m.sigma, vec![4, 2, 0, 3, 5, 1]);
    }

    #[test]
    fn vcycle_valid_monotone_and_improves() {
        let (g, h, o) = setup(256, 3);
        let spec = AlgorithmSpec::parse("topdown+Nc3").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 32 };
        let (ml, out) = run_vcycle(&g, &h, &o, &spec, &cfg, 7, 8);
        assert_eq!(out.levels.len(), ml.levels.len() + 1);
        assert_eq!(out.level_mappings.len(), out.levels.len());
        for (i, (stat, m)) in out.levels.iter().zip(&out.level_mappings).enumerate() {
            m.validate().unwrap_or_else(|e| panic!("level {i}: {e}"));
            assert!(stat.objective <= stat.objective_initial, "level {i} worsened");
            assert_eq!(m.n(), stat.n);
        }
        assert_eq!(out.mapping.sigma, out.level_mappings.last().unwrap().sigma);
        assert_eq!(out.objective, objective(&g, &o, &out.mapping));
        assert!(out.objective <= out.objective_initial);
        assert!(out.stats.evaluated > 0);
    }

    #[test]
    fn vcycle_deterministic_for_fixed_seeds() {
        let (g, h, o) = setup(128, 4);
        let spec = AlgorithmSpec::parse("topdown+Nc2").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 16 };
        let a = run_vcycle(&g, &h, &o, &spec, &cfg, 11, 12).1;
        let b = run_vcycle(&g, &h, &o, &spec, &cfg, 11, 12).1;
        assert_eq!(a.mapping.sigma, b.mapping.sigma);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.levels, b.levels);
    }

    #[test]
    fn vcycle_degenerates_without_coarsening() {
        // coarsen_limit above n: no levels, the V-cycle is construct+refine
        let (g, h, o) = setup(128, 5);
        let spec = AlgorithmSpec::parse("mm+Nc1").unwrap();
        let cfg = MlConfig { max_levels: 8, coarsen_limit: 4096 };
        let (ml, out) = run_vcycle(&g, &h, &o, &spec, &cfg, 13, 14);
        assert!(ml.levels.is_empty());
        assert_eq!(out.levels.len(), 1);
        out.mapping.validate().unwrap();
    }

    #[test]
    fn vcycle_not_worse_than_projection_baseline() {
        // the whole point: refined-at-every-level beats (or ties) the raw
        // projected construction
        let (g, h, o) = setup(256, 6);
        let spec = AlgorithmSpec::parse("topdown+Nc5").unwrap();
        let cfg = MlConfig::default();
        let (_, out) = run_vcycle(&g, &h, &o, &spec, &cfg, 15, 16);
        assert!(
            out.objective < out.objective_initial,
            "{} vs {}",
            out.objective,
            out.objective_initial
        );
    }
}
