//! Process mapping as sparse quadratic assignment — the paper's core.
//!
//! * [`hierarchy`] — machine model `S = a1:…:ak`, `D = d1:…:dk` and the
//!   implicit/explicit distance oracles (§3.4).
//! * [`objective`] — `J(C,D,Π)`, vertex contributions `Γ`, the fast
//!   `O(d_u+d_v)` swap engine (§3.2) and the dense `O(n)` baseline.
//! * [`construct`] — initial mappings: Top-Down, Bottom-Up (§3.1) and all
//!   compared baselines (Müller-Merbach, GreedyAllC, RCB, identity, random).
//! * [`local_search`] — the `N²`, `N_p` and `N_C^d` neighborhoods (§3.3).
//! * [`algorithms`] — a registry tying the above into named end-to-end
//!   configurations for the CLI / coordinator / bench harness.

pub mod algorithms;
pub mod construct;
pub mod hierarchy;
pub mod infer;
pub mod local_search;
pub mod objective;

pub use hierarchy::{DistanceOracle, Hierarchy};
pub use objective::{objective, DenseEngine, Mapping, SwapEngine};
