//! Process mapping as sparse quadratic assignment — the paper's core.
//!
//! The machine model (`S`/`D` hierarchies, grids, tori, explicit matrices)
//! lives in [`crate::model::topology`]; the central types ([`Machine`],
//! [`Topology`], [`Hierarchy`]) are re-exported here for convenience.
//!
//! * [`objective`] — `J(C,D,Π)`, vertex contributions `Γ`, the fast
//!   `O(d_u+d_v)` swap engine (§3.2) and the dense `O(n)` baseline, both
//!   dispatching once per call to the concrete [`Topology`].
//! * [`construct`] — initial mappings: Top-Down, Bottom-Up (§3.1) and all
//!   compared baselines (Müller-Merbach, GreedyAllC, RCB, identity, random).
//! * [`refine`] — the `N²`, `N_p`, `N_C^d` and 3-cycle searches (§3.3, §5)
//!   as [`refine::Refiner`]s over the [`refine::Swapper`] engine interface,
//!   plus the gain-cached queues (`gc:nc<d>` pair-only, `gc:nccyc<d>` the
//!   unified swap + queued-rotation move class).
//! * [`multilevel`] — the coarsen → map → uncoarsen+refine V-cycle built on
//!   [`crate::partition::coarsen`] groupings and per-topology machine folds.
//! * [`algorithms`] — a registry tying the above into named end-to-end
//!   configurations (`topdown+Nc10`, `ml:topdown+Nc5`, …) for the CLI /
//!   coordinator / bench harness.

pub mod algorithms;
pub mod construct;
pub mod multilevel;
pub mod objective;
pub mod refine;

pub use crate::model::topology::{
    ExplicitTopology, GridTopology, Hierarchy, Machine, Topology, TorusTopology,
};
pub use multilevel::{LevelStat, MlConfig, MlHierarchy};
pub use objective::{objective, DenseEngine, Mapping, SwapEngine};
pub use refine::{refiner_for, Refiner, SearchStats, Swapper};
