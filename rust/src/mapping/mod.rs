//! Process mapping as sparse quadratic assignment — the paper's core.
//!
//! * [`hierarchy`] — machine model `S = a1:…:ak`, `D = d1:…:dk` and the
//!   implicit/explicit distance oracles (§3.4).
//! * [`objective`] — `J(C,D,Π)`, vertex contributions `Γ`, the fast
//!   `O(d_u+d_v)` swap engine (§3.2) and the dense `O(n)` baseline.
//! * [`construct`] — initial mappings: Top-Down, Bottom-Up (§3.1) and all
//!   compared baselines (Müller-Merbach, GreedyAllC, RCB, identity, random).
//! * [`refine`] — the `N²`, `N_p`, `N_C^d` and 3-cycle searches (§3.3, §5)
//!   as [`refine::Refiner`]s over the [`refine::Swapper`] engine interface.
//! * [`multilevel`] — the coarsen → map → uncoarsen+refine V-cycle built on
//!   [`crate::partition::coarsen`] matchings and the refiner framework.
//! * [`algorithms`] — a registry tying the above into named end-to-end
//!   configurations (`topdown+Nc10`, `ml:topdown+Nc5`, …) for the CLI /
//!   coordinator / bench harness.

pub mod algorithms;
pub mod construct;
pub mod hierarchy;
pub mod infer;
pub mod multilevel;
pub mod objective;
pub mod refine;

pub use hierarchy::{DistanceOracle, Hierarchy};
pub use multilevel::{LevelStat, MlConfig, MlHierarchy};
pub use objective::{objective, DenseEngine, Mapping, SwapEngine};
pub use refine::{refiner_for, Refiner, SearchStats, Swapper};
