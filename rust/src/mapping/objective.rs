//! QAP objective, vertex contributions, and the fast swap engine (§3.2).
//!
//! The objective is evaluated in the inverse-permutation form
//!
//! ```text
//! J(C, D, Π) = Σ_{(u,v) ∈ E[C]} C_{u,v} · D_{σ(u), σ(v)},     σ = Π⁻¹
//! ```
//!
//! where `σ(u)` is the PE hosting process `u`. [`SwapEngine`] maintains the
//! per-vertex contributions `Γ_σ(u) = Σ_{v ∈ Γ(u)} C_{u,v} D_{σ(u),σ(v)}`
//! so that a swap evaluates and applies in `O(d_u + d_v)` time — the paper's
//! central speed contribution. [`DenseEngine`] reimplements the *slow*
//! baseline of Brandfass et al. (dense matrices, `O(n)` per update) used as
//! the comparison point of Table 1/Figure 1.

use crate::graph::{AppliedEdge, Graph, NodeId};
use crate::model::topology::{with_topology, Machine, Topology};

/// An assignment of processes to PEs: `sigma[u]` = PE of process `u`
/// (the paper's `Π⁻¹`). Always a bijection `0..n -> 0..n`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    pub sigma: Vec<u32>,
}

impl Mapping {
    /// The identity assignment.
    pub fn identity(n: usize) -> Mapping {
        Mapping { sigma: (0..n as u32).collect() }
    }

    /// Number of processes.
    pub fn n(&self) -> usize {
        self.sigma.len()
    }

    /// Verify bijectivity.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.sigma.len();
        let mut seen = vec![false; n];
        for &p in &self.sigma {
            if p as usize >= n {
                return Err(format!("PE {p} out of range (n={n})"));
            }
            if seen[p as usize] {
                return Err(format!("PE {p} assigned twice"));
            }
            seen[p as usize] = true;
        }
        Ok(())
    }

    /// The inverse map (PE -> process), the paper's `Π`.
    pub fn inverse(&self) -> Vec<u32> {
        let mut inv = vec![0u32; self.sigma.len()];
        for (u, &p) in self.sigma.iter().enumerate() {
            inv[p as usize] = u as u32;
        }
        inv
    }
}

/// `J(C, D, σ)` from scratch in `O(n + m)` oracle queries (§3.2: "we can
/// compute the initial objective in O(n+m) time").
pub fn objective(comm: &Graph, oracle: &Machine, mapping: &Mapping) -> u64 {
    with_topology!(oracle, t => objective_t(comm, t, mapping))
}

/// Monomorphized inner loop of [`objective`] (also the entry point for
/// callers already holding a concrete [`Topology`]).
pub fn objective_t<T: Topology + ?Sized>(comm: &Graph, topo: &T, mapping: &Mapping) -> u64 {
    let mut j = 0u64;
    for u in 0..comm.n() as NodeId {
        let pu = mapping.sigma[u as usize];
        for (v, c) in comm.edges(u) {
            if v > u {
                j += c * topo.distance(pu, mapping.sigma[v as usize]);
            }
        }
    }
    j
}

/// Everything a [`SwapEngine`] accumulates that outlives the engine's borrow
/// of the communication graph: the assignment, all `Γ`, the per-vertex move
/// versions, the move epoch, and `J`.
///
/// This is the warm-start currency of the REMAP path
/// ([`crate::api::MapSession::remap`]): the session captures these parts at a
/// drained local optimum, patches the graph in place (which the engine's
/// shared borrow would forbid while it lives), and resurrects the engine with
/// [`SwapEngine::from_warm`]. The **version vector must round-trip** — a
/// rebuilt engine zeroes it, which would make every stamp a previous search
/// recorded compare as fresh and let stale cached gains be applied blind.
#[derive(Debug, Clone)]
pub struct WarmParts {
    pub mapping: Mapping,
    pub gamma: Vec<u64>,
    pub version: Vec<u64>,
    pub moves: u64,
    pub j: u64,
}

/// The fast sparse swap engine (the paper's contribution, §3.2).
pub struct SwapEngine<'a> {
    comm: &'a Graph,
    oracle: &'a Machine,
    sigma: Vec<u32>,
    /// `Γ_σ(u)`: contribution of vertex `u` to the objective (each edge is
    /// counted in both endpoints' Γ, so `Σ Γ = 2J`).
    gamma: Vec<u64>,
    /// Per-vertex move versions: every applied move bumps the counters of
    /// `u`, `v` *and all their communication neighbors* — exactly the set of
    /// vertices whose Γ (and therefore any pair or rotation gain they
    /// participate in) the move can change. Gain-cached refiners stamp these
    /// at evaluation time and re-evaluate lazily when a stamp goes stale
    /// ([`crate::mapping::refine::GainCacheNc`]). Stored as u64 so stamps
    /// never alias: a u32 counter wraps after 2^32 bumps of one vertex, and
    /// an aliased stamp would let a stale cached gain be applied blind.
    version: Vec<u64>,
    /// Global move epoch: total number of applied moves (a rotation counts
    /// as its two constituent swaps). Monotone; cheap staleness signal for
    /// callers that do not track per-vertex versions.
    moves: u64,
    /// Current objective value.
    j: u64,
    /// Number of swaps applied (statistics for the harness).
    pub swaps_applied: u64,
}

impl<'a> SwapEngine<'a> {
    /// Build the engine in `O(n + m)`: compute all `Γ` and `J`.
    pub fn new(comm: &'a Graph, oracle: &'a Machine, mapping: Mapping) -> SwapEngine<'a> {
        Self::with_gamma_buf(comm, oracle, mapping, Vec::new())
    }

    /// Like [`Self::new`], but reuse a previously-allocated `Γ` buffer
    /// instead of allocating a fresh one. [`crate::api::MapSession`] passes
    /// its per-repetition scratch here so best-of-N jobs stop reallocating;
    /// recover the buffer afterwards with [`Self::into_parts`].
    pub fn with_gamma_buf(
        comm: &'a Graph,
        oracle: &'a Machine,
        mapping: Mapping,
        mut gamma: Vec<u64>,
    ) -> SwapEngine<'a> {
        debug_assert_eq!(comm.n(), mapping.n());
        let sigma = mapping.sigma;
        gamma.clear();
        gamma.resize(comm.n(), 0);
        let mut j = 0u64;
        // §Perf: the topology is dispatched once for the whole O(n+m) fill,
        // monomorphizing the inner loops (the PR 3 once-per-call pattern).
        with_topology!(oracle, t => {
            for u in 0..comm.n() as NodeId {
                let pu = sigma[u as usize];
                let mut gu = 0u64;
                for (v, c) in comm.edges(u) {
                    let contrib = c * t.distance(pu, sigma[v as usize]);
                    gu += contrib;
                    if v > u {
                        j += contrib;
                    }
                }
                gamma[u as usize] = gu;
            }
        });
        let version = vec![0u64; comm.n()];
        SwapEngine { comm, oracle, sigma, gamma, version, moves: 0, j, swaps_applied: 0 }
    }

    /// Decompose into the final assignment and the `Γ` scratch buffer (for
    /// reuse by the next repetition; see [`Self::with_gamma_buf`]).
    pub fn into_parts(self) -> (Mapping, Vec<u64>) {
        (Mapping { sigma: self.sigma }, self.gamma)
    }

    /// Decompose into the full warm state ([`WarmParts`]) so the engine can
    /// be resurrected later with [`Self::from_warm`] without the `O(n + m)`
    /// rebuild — and, crucially, without resetting the move versions.
    pub fn into_warm_parts(self) -> WarmParts {
        WarmParts {
            mapping: Mapping { sigma: self.sigma },
            gamma: self.gamma,
            version: self.version,
            moves: self.moves,
            j: self.j,
        }
    }

    /// Resurrect an engine from previously captured [`WarmParts`] in `O(1)`
    /// (no Γ fill, no objective pass). The caller guarantees `parts` were
    /// captured against a graph whose weights `gamma`/`j` still describe —
    /// after an in-place graph patch, follow up with [`Self::apply_deltas`]
    /// on the applied-edge records to bring Γ and J to the new weights.
    pub fn from_warm(comm: &'a Graph, oracle: &'a Machine, parts: WarmParts) -> SwapEngine<'a> {
        debug_assert_eq!(comm.n(), parts.mapping.n());
        debug_assert_eq!(comm.n(), parts.gamma.len());
        debug_assert_eq!(comm.n(), parts.version.len());
        SwapEngine {
            comm,
            oracle,
            sigma: parts.mapping.sigma,
            gamma: parts.gamma,
            version: parts.version,
            moves: parts.moves,
            j: parts.j,
            swaps_applied: 0,
        }
    }

    /// Patch `Γ` and `J` for a batch of edge-weight changes in `O(|Δ|)`
    /// oracle queries — the REMAP alternative to the `O(n + m)` rebuild.
    ///
    /// Preconditions: `self.comm` already carries the **new** weights (the
    /// records come out of [`Graph::apply_deltas`], which mutates the graph
    /// and reports old→new per edge), while `Γ`/`J` still describe the old
    /// ones. For each changed edge `{u, v}` the objective shifts by
    /// `δ = (w_new − w_old) · D(σ(u), σ(v))`; `Γ(u)` and `Γ(v)` each absorb
    /// the same `δ` (every edge is counted in both endpoints' Γ), so the
    /// `ΣΓ = 2J` invariant is preserved term by term. Records are sequential,
    /// so repeated updates of one pair telescope.
    ///
    /// Only the *endpoints'* move versions bump: σ is untouched, so the only
    /// cached gains invalidated are those of moves having `u` or `v` as a
    /// vertex — their rows are the only ones whose weights entered the gain.
    /// The move epoch is unchanged (no move was applied). Inserts are just
    /// `w_old = 0` records; deletes would be `w_new = 0` (the edge stays in
    /// the CSR structure with weight 0, contributing nothing).
    pub fn apply_deltas(&mut self, records: &[AppliedEdge]) {
        let oracle = self.oracle;
        with_topology!(oracle, t => {
            for r in records {
                if r.old_w == r.new_w {
                    continue;
                }
                let d = t.distance(self.sigma[r.u as usize], self.sigma[r.v as usize]) as i64;
                let delta = (r.new_w as i64 - r.old_w as i64) * d;
                self.gamma[r.u as usize] = (self.gamma[r.u as usize] as i64 + delta) as u64;
                self.gamma[r.v as usize] = (self.gamma[r.v as usize] as i64 + delta) as u64;
                self.j = (self.j as i64 + delta) as u64;
                self.version[r.u as usize] = self.version[r.u as usize].wrapping_add(1);
                self.version[r.v as usize] = self.version[r.v as usize].wrapping_add(1);
            }
        });
    }

    /// Current objective `J`.
    #[inline]
    pub fn objective(&self) -> u64 {
        self.j
    }

    /// Current assignment.
    pub fn mapping(&self) -> Mapping {
        Mapping { sigma: self.sigma.clone() }
    }

    /// PE of process `u`.
    #[inline]
    pub fn pe_of(&self, u: NodeId) -> u32 {
        self.sigma[u as usize]
    }

    /// Γ value of `u` (exposed for invariant tests).
    #[inline]
    pub fn gamma_of(&self, u: NodeId) -> u64 {
        self.gamma[u as usize]
    }

    /// Move version of `u`: bumped (wrapping, but unreachable at u64 width)
    /// by every applied move that can change a gain involving `u` — i.e.
    /// whenever `u` is an endpoint or a communication neighbor of an
    /// endpoint of the move.
    #[inline]
    pub fn version_of(&self, u: NodeId) -> u64 {
        self.version[u as usize]
    }

    /// Global move epoch (monotone count of applied swaps; a rotation
    /// contributes two).
    #[inline]
    pub fn moves_epoch(&self) -> u64 {
        self.moves
    }

    /// Gain of swapping the PEs of processes `u` and `v` (positive = the
    /// objective decreases by that amount). `O(d_u + d_v)` oracle queries.
    ///
    /// §Perf: the machine is dispatched to its concrete [`Topology`] once
    /// per *call*, not once per edge — the inner loops are monomorphized
    /// over the concrete topology.
    pub fn swap_gain(&self, u: NodeId, v: NodeId) -> i64 {
        with_topology!(self.oracle, t => self.swap_gain_with(u, v, t))
    }

    #[inline]
    fn swap_gain_with<T: Topology>(&self, u: NodeId, v: NodeId, topo: &T) -> i64 {
        debug_assert_ne!(u, v);
        let pu = self.sigma[u as usize];
        let pv = self.sigma[v as usize];
        if pu == pv {
            return 0;
        }
        let mut delta = 0i64; // new - old cost over affected edges
        for (x, c) in self.comm.edges(u) {
            if x == v {
                continue; // the (u,v) edge cost is invariant under the swap
            }
            let px = self.sigma[x as usize];
            delta += c as i64 * (topo.distance(pv, px) as i64 - topo.distance(pu, px) as i64);
        }
        for (x, c) in self.comm.edges(v) {
            if x == u {
                continue;
            }
            let px = self.sigma[x as usize];
            delta += c as i64 * (topo.distance(pu, px) as i64 - topo.distance(pv, px) as i64);
        }
        -delta
    }

    /// Apply the swap, updating `σ`, all affected `Γ`, move versions and `J`
    /// in `O(d_u + d_v)` (§3.2's update procedure).
    ///
    /// §Perf: like [`Self::swap_gain`], the machine is dispatched once per
    /// *call* — the inner loops are monomorphized over the concrete
    /// topology.
    pub fn do_swap(&mut self, u: NodeId, v: NodeId) {
        let oracle = self.oracle;
        with_topology!(oracle, t => self.do_swap_with(u, v, t))
    }

    fn do_swap_with<T: Topology>(&mut self, u: NodeId, v: NodeId, topo: &T) {
        let dist = |p: u32, q: u32| topo.distance(p, q);
        debug_assert_ne!(u, v);
        let pu = self.sigma[u as usize];
        let pv = self.sigma[v as usize];
        // subtract old contributions of u and v from J (each edge (u,x)
        // appears once in Γ(u); J counts undirected edges once, and the
        // (u,v) edge sits in both Γs). Its cost is invariant under the swap
        // (D is symmetric), so one lookup serves both sides of the update.
        let cuv = self.comm.edge_weight(u, v); // rarely present; degree-bounded scan
        let duv = cuv.map(|c| c * dist(pu, pv)).unwrap_or(0);
        self.j -= self.gamma[u as usize] + self.gamma[v as usize] - duv;

        // retract edge contributions from the neighbors' Γ; every neighbor's
        // version bumps — their Γ (and any gain they participate in) changes
        for (x, c) in self.comm.edges(u) {
            if x != v {
                self.gamma[x as usize] -= c * dist(pu, self.sigma[x as usize]);
            }
            self.version[x as usize] = self.version[x as usize].wrapping_add(1);
        }
        for (x, c) in self.comm.edges(v) {
            if x != u {
                self.gamma[x as usize] -= c * dist(pv, self.sigma[x as usize]);
            }
            self.version[x as usize] = self.version[x as usize].wrapping_add(1);
        }

        // the swap itself
        self.sigma[u as usize] = pv;
        self.sigma[v as usize] = pu;

        // recompute Γ(u), Γ(v); push new edge contributions to neighbors
        let mut gu = 0u64;
        for (x, c) in self.comm.edges(u) {
            let contrib = c * dist(pv, self.sigma[x as usize]);
            gu += contrib;
            if x != v {
                self.gamma[x as usize] += contrib;
            }
        }
        let mut gv = 0u64;
        for (x, c) in self.comm.edges(v) {
            let contrib = c * dist(pu, self.sigma[x as usize]);
            gv += contrib;
            if x != u {
                self.gamma[x as usize] += contrib;
            }
        }
        self.gamma[u as usize] = gu;
        self.gamma[v as usize] = gv;

        // add new contributions to J (the (u,v) edge again counted once)
        self.j += gu + gv - duv;
        self.version[u as usize] = self.version[u as usize].wrapping_add(1);
        self.version[v as usize] = self.version[v as usize].wrapping_add(1);
        self.moves += 1;
        self.swaps_applied += 1;
    }

    /// Gain of rotating the PEs of three processes along the cycle
    /// `u -> v -> w -> u` (u gets v's PE, v gets w's, w gets u's). The
    /// paper's §5 names cyclic exchanges as future work; this implements
    /// them with the same Γ machinery in `O(d_u + d_v + d_w)`.
    ///
    /// §Perf: like [`Self::swap_gain`], the machine is dispatched once per
    /// *call* — the inner loops are monomorphized over the concrete
    /// topology.
    pub fn rotate3_gain(&self, u: NodeId, v: NodeId, w: NodeId) -> i64 {
        with_topology!(self.oracle, t => self.rotate3_gain_with(u, v, w, t))
    }

    #[inline]
    fn rotate3_gain_with<T: Topology>(&self, u: NodeId, v: NodeId, w: NodeId, topo: &T) -> i64 {
        let dist = |p: u32, q: u32| topo.distance(p, q);
        debug_assert!(u != v && v != w && u != w);
        let pu = self.sigma[u as usize];
        let pv = self.sigma[v as usize];
        let pw = self.sigma[w as usize];
        // new PE of each rotated vertex
        let np = [(u, pv), (v, pw), (w, pu)];
        let mut delta = 0i64;
        for &(a, pa_new) in &np {
            let pa_old = self.sigma[a as usize];
            for (x, c) in self.comm.edges(a) {
                if x == u || x == v || x == w {
                    continue; // intra-triple edges handled separately
                }
                let px = self.sigma[x as usize];
                delta += c as i64 * (dist(pa_new, px) as i64 - dist(pa_old, px) as i64);
            }
        }
        // intra-triple edges: each unordered pair once, old vs new distance
        for (a, b, pa_new, pb_new) in
            [(u, v, pv, pw), (u, w, pv, pu), (v, w, pw, pu)]
        {
            if let Some(c) = self.comm.edge_weight(a, b) {
                let old = dist(self.sigma[a as usize], self.sigma[b as usize]);
                let new = dist(pa_new, pb_new);
                delta += c as i64 * (new as i64 - old as i64);
            }
        }
        -delta
    }

    /// Apply the 3-cycle rotation `u -> v -> w -> u` (Γ and J updated in
    /// `O(d_u + d_v + d_w)` by decomposing into two swaps).
    pub fn do_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) {
        // (u v w) = swap(u, v) then swap(v, w):
        //   after swap(u,v): u has pv, v has pu
        //   after swap(v,w): v has pw, w has pu  => u:pv, v:pw, w:pu ✓
        self.do_swap(u, v);
        self.do_swap(v, w);
        self.swaps_applied -= 1; // count the rotation as one move
    }

    /// Apply the rotation only if it strictly improves; returns the gain.
    pub fn try_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) -> Option<i64> {
        let gain = self.rotate3_gain(u, v, w);
        if gain > 0 {
            self.do_rotate3(u, v, w);
            Some(gain)
        } else {
            None
        }
    }

    /// Apply the swap only if it strictly improves; returns the gain if so.
    pub fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
        let gain = self.swap_gain(u, v);
        if gain > 0 {
            self.do_swap(u, v);
            Some(gain)
        } else {
            None
        }
    }

    /// Recompute everything from scratch (test oracle; O(n+m)).
    pub fn recompute_objective(&self) -> u64 {
        objective(self.comm, self.oracle, &Mapping { sigma: self.sigma.clone() })
    }

    /// Γ-sum invariant: `Σ_u Γ(u) == 2·J` (test oracle).
    pub fn gamma_invariant_holds(&self) -> bool {
        let sum: u64 = self.gamma.iter().sum();
        sum == 2 * self.j
    }
}

/// The *slow* dense engine of Brandfass et al.: `C` and `D` stored as full
/// `n×n` matrices, objective initialization in `O(n²)`, gain and update in
/// `O(n)`. Only used as the Table 1 baseline; weights are `u32` to keep the
/// quadratic memory in check at the larger benchmark sizes.
pub struct DenseEngine {
    n: usize,
    c: Vec<u32>,
    d: Vec<u32>,
    sigma: Vec<u32>,
    j: u64,
    pub swaps_applied: u64,
}

impl DenseEngine {
    /// Densify the sparse inputs — this is exactly what the original codes
    /// did ("both the communication pattern as well as the distances between
    /// the PEs are given as complete matrices", §3.2). Any [`Machine`]
    /// densifies the same way; the dispatch is paid once per matrix fill.
    pub fn new(comm: &Graph, oracle: &Machine, mapping: Mapping) -> DenseEngine {
        let n = comm.n();
        let mut c = vec![0u32; n * n];
        for u in 0..n as NodeId {
            for (v, w) in comm.edges(u) {
                c[u as usize * n + v as usize] = w as u32;
            }
        }
        let mut d = vec![0u32; n * n];
        with_topology!(oracle, t => {
            for p in 0..n as u32 {
                for q in 0..n as u32 {
                    d[p as usize * n + q as usize] = t.distance(p, q) as u32;
                }
            }
        });
        let sigma = mapping.sigma;
        let j = dense_objective(&c, &d, &sigma, n);
        DenseEngine { n, c, d, sigma, j, swaps_applied: 0 }
    }

    /// Number of processes the dense matrices were built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Re-initialize for a new start mapping, reusing the dense `C` and `D`
    /// matrices — the `O(n²)` memory fills are the expensive part of
    /// construction, and they only depend on the (fixed) instance.
    /// [`crate::api::MapSession`] uses this across repetitions.
    pub fn reset(&mut self, mapping: Mapping) {
        debug_assert_eq!(mapping.n(), self.n);
        self.sigma = mapping.sigma;
        self.j = dense_objective(&self.c, &self.d, &self.sigma, self.n);
        self.swaps_applied = 0;
    }

    /// Patch the dense `C` matrix and `J` for a batch of edge-weight changes
    /// — the dense analogue of [`SwapEngine::apply_deltas`]. Unlike the
    /// sparse engine this one owns its matrices, so the patch is entirely
    /// self-contained: both mirror entries of `C` are overwritten and `J`
    /// shifts by `(w_new − w_old) · D(σ(u), σ(v))` per record.
    pub fn apply_deltas(&mut self, records: &[AppliedEdge]) {
        let n = self.n;
        for r in records {
            let (u, v) = (r.u as usize, r.v as usize);
            debug_assert!(u < n && v < n && u != v);
            self.c[u * n + v] = r.new_w as u32;
            self.c[v * n + u] = r.new_w as u32;
            if r.old_w != r.new_w {
                let d = self.d[self.sigma[u] as usize * n + self.sigma[v] as usize] as i64;
                let delta = (r.new_w as i64 - r.old_w as i64) * d;
                self.j = (self.j as i64 + delta) as u64;
            }
        }
    }

    /// Current objective.
    pub fn objective(&self) -> u64 {
        self.j
    }

    /// Current assignment.
    pub fn mapping(&self) -> Mapping {
        Mapping { sigma: self.sigma.clone() }
    }

    /// PE of process `u` (cheap accessor — `mapping()` clones the whole
    /// assignment and must not be used for per-pair position lookups).
    #[inline]
    pub fn pe_of(&self, u: NodeId) -> u32 {
        self.sigma[u as usize]
    }

    /// Gain of swapping processes `u`, `v` — scans the full rows: `O(n)`.
    pub fn swap_gain(&self, u: NodeId, v: NodeId) -> i64 {
        let (u, v) = (u as usize, v as usize);
        let pu = self.sigma[u] as usize;
        let pv = self.sigma[v] as usize;
        if pu == pv {
            return 0;
        }
        let n = self.n;
        let mut delta = 0i64;
        // full-row scan, including the zero entries — exactly what the
        // original dense implementation does and the point of Table 1
        // (no != 0 shortcut: the dense code pays for every element).
        for x in 0..n {
            if x == u || x == v {
                continue;
            }
            let px = self.sigma[x] as usize;
            let dd = self.d[pv * n + px] as i64 - self.d[pu * n + px] as i64;
            delta += self.c[u * n + x] as i64 * dd;
            delta -= self.c[v * n + x] as i64 * dd;
        }
        -delta
    }

    /// Apply a swap whose gain the caller already computed — the `O(1)`
    /// bookkeeping half of the move, without the second `O(n)` row scan.
    /// Shared by [`Self::do_swap`], [`Self::try_swap`] and the
    /// `Swapper::do_swap_with_gain` override (gain-cached refiners apply
    /// provably-fresh pops without re-scanning). The gain must be exact —
    /// `J` is updated by subtraction, not recomputed.
    #[inline]
    pub(crate) fn apply_swap_with_gain(&mut self, u: NodeId, v: NodeId, gain: i64) {
        self.sigma.swap(u as usize, v as usize);
        self.j = (self.j as i64 - gain) as u64;
        self.swaps_applied += 1;
    }

    /// Apply the swap (`O(n)` bookkeeping as in the original: the dense code
    /// pays a full row scan to apply a move).
    pub fn do_swap(&mut self, u: NodeId, v: NodeId) {
        let gain = self.swap_gain(u, v);
        self.apply_swap_with_gain(u, v, gain);
    }

    /// Apply only on strict improvement (the `O(n)` gain scan runs once).
    pub fn try_swap(&mut self, u: NodeId, v: NodeId) -> Option<i64> {
        let gain = self.swap_gain(u, v);
        if gain > 0 {
            self.apply_swap_with_gain(u, v, gain);
            Some(gain)
        } else {
            None
        }
    }

    /// Gain of rotating the PEs of three processes along the cycle
    /// `u -> v -> w -> u` (u gets v's PE, v gets w's, w gets u's) — the same
    /// move [`SwapEngine::rotate3_gain`] evaluates sparsely, here via the
    /// dense full-row scan (`O(n)`, matching this engine's cost model).
    pub fn rotate3_gain(&self, u: NodeId, v: NodeId, w: NodeId) -> i64 {
        debug_assert!(u != v && v != w && u != w);
        let (u, v, w) = (u as usize, v as usize, w as usize);
        let n = self.n;
        let pu = self.sigma[u] as usize;
        let pv = self.sigma[v] as usize;
        let pw = self.sigma[w] as usize;
        // new PEs after the rotation: u -> pv, v -> pw, w -> pu
        let mut delta = 0i64;
        for x in 0..n {
            if x == u || x == v || x == w {
                continue; // intra-triple edges handled separately
            }
            let px = self.sigma[x] as usize;
            delta += self.c[u * n + x] as i64
                * (self.d[pv * n + px] as i64 - self.d[pu * n + px] as i64);
            delta += self.c[v * n + x] as i64
                * (self.d[pw * n + px] as i64 - self.d[pv * n + px] as i64);
            delta += self.c[w * n + x] as i64
                * (self.d[pu * n + px] as i64 - self.d[pw * n + px] as i64);
        }
        // intra-triple edges: each unordered pair once, new vs old distance
        delta += self.c[u * n + v] as i64
            * (self.d[pv * n + pw] as i64 - self.d[pu * n + pv] as i64);
        delta += self.c[u * n + w] as i64
            * (self.d[pv * n + pu] as i64 - self.d[pu * n + pw] as i64);
        delta += self.c[v * n + w] as i64
            * (self.d[pw * n + pu] as i64 - self.d[pv * n + pw] as i64);
        -delta
    }

    /// Apply a rotation whose gain the caller already computed (`O(1)`;
    /// shared by [`Self::do_rotate3`], [`Self::try_rotate3`] and the
    /// `Swapper::do_rotate3_with_gain` override — the unified gain-cache
    /// refiner applies provably-fresh rotation pops without re-scanning).
    /// The gain must be exact — `J` is updated by subtraction.
    #[inline]
    pub(crate) fn apply_rotate3_with_gain(&mut self, u: NodeId, v: NodeId, w: NodeId, gain: i64) {
        let pu = self.sigma[u as usize];
        self.sigma[u as usize] = self.sigma[v as usize];
        self.sigma[v as usize] = self.sigma[w as usize];
        self.sigma[w as usize] = pu;
        self.j = (self.j as i64 - gain) as u64;
        self.swaps_applied += 1;
    }

    /// Apply the 3-cycle rotation `u -> v -> w -> u`.
    pub fn do_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) {
        let gain = self.rotate3_gain(u, v, w);
        self.apply_rotate3_with_gain(u, v, w, gain);
    }

    /// Apply the rotation only if it strictly improves; returns the gain.
    /// (Mirrors [`Self::try_swap`]: the `O(n)` gain scan runs once, not
    /// twice.)
    pub fn try_rotate3(&mut self, u: NodeId, v: NodeId, w: NodeId) -> Option<i64> {
        let gain = self.rotate3_gain(u, v, w);
        if gain > 0 {
            self.apply_rotate3_with_gain(u, v, w, gain);
            Some(gain)
        } else {
            None
        }
    }

    /// Recompute the objective from the dense matrices (test oracle).
    pub fn recompute_objective(&self) -> u64 {
        dense_objective(&self.c, &self.d, &self.sigma, self.n)
    }
}

/// `O(n²)` dense objective initialization shared by [`DenseEngine::new`] and
/// [`DenseEngine::reset`].
fn dense_objective(c: &[u32], d: &[u32], sigma: &[u32], n: usize) -> u64 {
    let mut j = 0u64;
    for u in 0..n {
        let pu = sigma[u] as usize;
        for v in (u + 1)..n {
            let cuv = c[u * n + v];
            if cuv != 0 {
                j += cuv as u64 * d[pu * n + sigma[v] as usize] as u64;
            }
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::model::topology::Hierarchy;
    use crate::util::Rng;

    fn setup(n_exp: usize, seed: u64) -> (Graph, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << n_exp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1 << n_exp) / 64], vec![1, 10, 100]).unwrap();
        (g, Machine::implicit(h))
    }

    #[test]
    fn identity_objective_matches_manual() {
        let g = crate::graph::from_edges(4, &[(0, 1, 3), (1, 2, 5), (2, 3, 2)]);
        let h = Hierarchy::new(vec![2, 2], vec![1, 10]).unwrap();
        let o = Machine::implicit(h);
        let m = Mapping::identity(4);
        // edges: (0,1): d(0,1)=1 -> 3; (1,2): d(1,2)=10 -> 50; (2,3): d=1 -> 2
        assert_eq!(objective(&g, &o, &m), 3 + 50 + 2);
    }

    #[test]
    fn gain_matches_recompute_random_swaps() {
        let (g, o) = setup(8, 1);
        let mut rng = Rng::new(2);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut eng = SwapEngine::new(&g, &o, m);
        for _ in 0..500 {
            let u = rng.index(g.n()) as NodeId;
            let mut v = rng.index(g.n()) as NodeId;
            if u == v {
                v = (v + 1) % g.n() as NodeId;
            }
            let before = eng.objective();
            let gain = eng.swap_gain(u, v);
            eng.do_swap(u, v);
            let after = eng.objective();
            assert_eq!(after as i64, before as i64 - gain, "swap ({u},{v})");
            assert_eq!(after, eng.recompute_objective(), "incremental J diverged");
        }
        assert!(eng.gamma_invariant_holds());
    }

    #[test]
    fn gamma_invariant_after_many_swaps() {
        let (g, o) = setup(7, 3);
        let mut rng = Rng::new(4);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(g.n()));
        for _ in 0..200 {
            let u = rng.index(g.n()) as NodeId;
            let v = (u as usize + 1 + rng.index(g.n() - 1)) as u32 % g.n() as u32;
            if u != v {
                eng.do_swap(u, v);
            }
        }
        assert!(eng.gamma_invariant_holds());
        for u in 0..g.n() as NodeId {
            // each Γ(u) individually correct
            let pu = eng.pe_of(u);
            let expect: u64 = g
                .edges(u)
                .map(|(x, c)| c * o.distance(pu, eng.pe_of(x)))
                .sum();
            assert_eq!(eng.gamma_of(u), expect, "gamma({u})");
        }
    }

    #[test]
    fn moves_touch_only_endpoints_and_neighbors() {
        // the gain-cache contract: a swap of (u, v) may change Γ and the
        // move version only for u, v and their communication neighbors, and
        // the gain of any pair entirely outside that set stays put
        let (g, o) = setup(7, 40);
        let mut rng = Rng::new(41);
        let n = g.n();
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(n) });
        for _ in 0..50 {
            let u = rng.index(n) as NodeId;
            let mut v = rng.index(n) as NodeId;
            if u == v {
                v = (v + 1) % n as NodeId;
            }
            let mut touched = vec![false; n];
            touched[u as usize] = true;
            touched[v as usize] = true;
            for &x in g.neighbors(u) {
                touched[x as usize] = true;
            }
            for &x in g.neighbors(v) {
                touched[x as usize] = true;
            }
            let gamma_before: Vec<u64> = (0..n as NodeId).map(|x| eng.gamma_of(x)).collect();
            let version_before: Vec<u64> = (0..n as NodeId).map(|x| eng.version_of(x)).collect();
            // control pairs fully outside the touched set
            let mut control: Vec<(NodeId, NodeId, i64)> = Vec::new();
            for _ in 0..20 {
                let a = rng.index(n) as NodeId;
                let b = rng.index(n) as NodeId;
                if a != b && !touched[a as usize] && !touched[b as usize] {
                    control.push((a, b, eng.swap_gain(a, b)));
                }
            }
            let epoch = eng.moves_epoch();
            eng.do_swap(u, v);
            assert_eq!(eng.moves_epoch(), epoch + 1);
            for x in 0..n as NodeId {
                if touched[x as usize] {
                    assert!(
                        eng.version_of(x) > version_before[x as usize],
                        "version({x}) not bumped"
                    );
                } else {
                    assert_eq!(
                        eng.version_of(x),
                        version_before[x as usize],
                        "version({x}) moved"
                    );
                    assert_eq!(eng.gamma_of(x), gamma_before[x as usize], "gamma({x}) moved");
                }
            }
            for (a, b, gain) in control {
                assert_eq!(eng.swap_gain(a, b), gain, "untouched pair ({a},{b}) gain changed");
            }
        }
    }

    #[test]
    fn version_counter_is_an_exact_u64_bump_count() {
        // the gain-cache stamp contract: `version_of` is an exact count of
        // the moves that touched the vertex, carried at u64 width through
        // the wrapping_add path — swapping an adjacent pair bumps each
        // endpoint twice (once as neighbor, once as endpoint), a
        // non-adjacent pair once each, and nothing silently truncates
        let g = crate::graph::from_edges(4, &[(0, 1, 3), (2, 3, 2)]);
        let h = Hierarchy::new(vec![2, 2], vec![1, 10]).unwrap();
        let o = Machine::implicit(h);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(4));
        for k in 1..=100u64 {
            eng.do_swap(0, 1); // adjacent: 0 and 1 each bump twice
            assert_eq!(eng.version_of(0), 2 * k);
            assert_eq!(eng.version_of(1), 2 * k);
            assert_eq!(eng.version_of(2), 0);
        }
        for k in 1..=100u64 {
            eng.do_swap(0, 2); // non-adjacent: each endpoint bumps once,
            // and each endpoint's neighbor (1 resp. 3) bumps once
            assert_eq!(eng.version_of(0), 200 + k);
            assert_eq!(eng.version_of(1), 200 + k);
            assert_eq!(eng.version_of(2), k);
            assert_eq!(eng.version_of(3), k);
        }
        assert_eq!(eng.objective(), eng.recompute_objective());
    }

    #[test]
    fn moves_epoch_counts_rotations_as_two_swaps() {
        let (g, o) = setup(6, 42);
        let mut eng = SwapEngine::new(&g, &o, Mapping::identity(g.n()));
        assert_eq!(eng.moves_epoch(), 0);
        eng.do_swap(0, 1);
        assert_eq!(eng.moves_epoch(), 1);
        eng.do_rotate3(0, 1, 2);
        assert_eq!(eng.moves_epoch(), 3);
        for x in [0u32, 1, 2] {
            assert!(eng.version_of(x) > 0, "version({x}) untouched by the rotation");
        }
        assert_eq!(eng.objective(), eng.recompute_objective());
    }

    #[test]
    fn dense_engine_agrees_with_sparse() {
        let (g, o) = setup(6, 5);
        let mut rng = Rng::new(6);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut fast = SwapEngine::new(&g, &o, m.clone());
        let mut slow = DenseEngine::new(&g, &o, m);
        assert_eq!(fast.objective(), slow.objective());
        for _ in 0..200 {
            let u = rng.index(g.n()) as NodeId;
            let mut v = rng.index(g.n()) as NodeId;
            if u == v {
                v = (v + 1) % g.n() as NodeId;
            }
            assert_eq!(fast.swap_gain(u, v), slow.swap_gain(u, v), "gain ({u},{v})");
            fast.do_swap(u, v);
            slow.do_swap(u, v);
            assert_eq!(fast.objective(), slow.objective());
        }
    }

    #[test]
    fn swap_same_pe_is_noop_gain() {
        let (g, o) = setup(6, 7);
        let eng = SwapEngine::new(&g, &o, Mapping::identity(g.n()));
        // gain of swapping u with itself is undefined; same-PE can't occur in
        // a bijection, but adjacent identical PEs can't either — check the
        // (u,v) edge invariance instead: swapping two connected processes
        // leaves their mutual term unchanged.
        let u = 0 as NodeId;
        let v = g.neighbors(0)[0];
        let mut e2 = SwapEngine::new(&g, &o, Mapping::identity(g.n()));
        let before_edge_cost = g.edge_weight(u, v).unwrap() * o.distance(e2.pe_of(u), e2.pe_of(v));
        e2.do_swap(u, v);
        let after_edge_cost = g.edge_weight(u, v).unwrap() * o.distance(e2.pe_of(u), e2.pe_of(v));
        assert_eq!(before_edge_cost, after_edge_cost);
        drop(eng);
    }

    #[test]
    fn try_swap_only_improves() {
        let (g, o) = setup(7, 8);
        let mut rng = Rng::new(9);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let mut last = eng.objective();
        let mut applied = 0;
        for _ in 0..2000 {
            let u = rng.index(g.n()) as NodeId;
            let mut v = rng.index(g.n()) as NodeId;
            if u == v {
                v = (v + 1) % g.n() as NodeId;
            }
            if eng.try_swap(u, v).is_some() {
                assert!(eng.objective() < last);
                applied += 1;
            } else {
                assert_eq!(eng.objective(), last);
            }
            last = eng.objective();
        }
        assert!(applied > 0, "random swaps on a random mapping should find improvements");
        assert_eq!(applied, eng.swaps_applied);
    }

    #[test]
    fn mapping_validate() {
        assert!(Mapping::identity(5).validate().is_ok());
        assert!(Mapping { sigma: vec![0, 0, 2] }.validate().is_err());
        assert!(Mapping { sigma: vec![0, 3] }.validate().is_err());
        let m = Mapping { sigma: vec![2, 0, 1] };
        assert_eq!(m.inverse(), vec![1, 2, 0]);
    }

    #[test]
    fn gamma_buffer_reuse_is_equivalent() {
        // with_gamma_buf over a dirty, wrongly-sized buffer must behave
        // exactly like a fresh engine, and into_parts must return the buffer
        let (g, o) = setup(7, 20);
        let mut rng = Rng::new(21);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let fresh = SwapEngine::new(&g, &o, m.clone());
        let dirty = vec![0xdeadbeefu64; 3];
        let mut reused = SwapEngine::with_gamma_buf(&g, &o, m, dirty);
        assert_eq!(fresh.objective(), reused.objective());
        for u in 0..g.n() as NodeId {
            assert_eq!(fresh.gamma_of(u), reused.gamma_of(u), "gamma({u})");
        }
        reused.do_swap(0, 1);
        let (mapping, gamma) = reused.into_parts();
        mapping.validate().unwrap();
        assert_eq!(gamma.len(), g.n());
    }

    #[test]
    fn rotate3_gain_matches_recompute() {
        let (g, o) = setup(7, 15);
        let mut rng = Rng::new(16);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        for _ in 0..300 {
            let n = g.n();
            let u = rng.index(n) as u32;
            let mut v = rng.index(n) as u32;
            let mut w = rng.index(n) as u32;
            if v == u {
                v = (v + 1) % n as u32;
            }
            while w == u || w == v {
                w = (w + 1) % n as u32;
            }
            let before = eng.objective();
            let gain = eng.rotate3_gain(u, v, w);
            eng.do_rotate3(u, v, w);
            assert_eq!(
                eng.objective() as i64,
                before as i64 - gain,
                "rotation ({u},{v},{w})"
            );
            assert_eq!(eng.objective(), eng.recompute_objective());
        }
        assert!(eng.gamma_invariant_holds());
        eng.mapping().validate().unwrap();
    }

    #[test]
    fn dense_rotate3_agrees_with_sparse() {
        // satellite of the Swapper unification: the dense engine's rotation
        // gain and application must match the fast engine's move for move
        let (g, o) = setup(6, 30);
        let mut rng = Rng::new(31);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut fast = SwapEngine::new(&g, &o, m.clone());
        let mut slow = DenseEngine::new(&g, &o, m);
        for _ in 0..200 {
            let n = g.n();
            let u = rng.index(n) as u32;
            let mut v = rng.index(n) as u32;
            let mut w = rng.index(n) as u32;
            if v == u {
                v = (v + 1) % n as u32;
            }
            while w == u || w == v {
                w = (w + 1) % n as u32;
            }
            assert_eq!(
                fast.rotate3_gain(u, v, w),
                slow.rotate3_gain(u, v, w),
                "rotation gain ({u},{v},{w})"
            );
            fast.do_rotate3(u, v, w);
            slow.do_rotate3(u, v, w);
            assert_eq!(fast.objective(), slow.objective());
            assert_eq!(fast.mapping(), slow.mapping());
        }
        assert_eq!(slow.objective(), slow.recompute_objective());
        slow.mapping().validate().unwrap();
    }

    #[test]
    fn dense_try_rotate3_only_improves() {
        let (g, o) = setup(6, 32);
        let mut rng = Rng::new(33);
        let mut eng = DenseEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        let mut last = eng.objective();
        for _ in 0..500 {
            let n = g.n();
            let u = rng.index(n) as u32;
            let mut v = rng.index(n) as u32;
            let mut w = rng.index(n) as u32;
            if v == u {
                v = (v + 1) % n as u32;
            }
            while w == u || w == v {
                w = (w + 1) % n as u32;
            }
            match eng.try_rotate3(u, v, w) {
                Some(gain) => {
                    assert!(gain > 0);
                    assert!(eng.objective() < last);
                }
                None => assert_eq!(eng.objective(), last),
            }
            last = eng.objective();
        }
        assert_eq!(eng.objective(), eng.recompute_objective());
    }

    #[test]
    fn warm_roundtrip_preserves_full_engine_state() {
        let (g, o) = setup(7, 50);
        let mut rng = Rng::new(51);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        for _ in 0..100 {
            let u = rng.index(g.n()) as NodeId;
            let mut v = rng.index(g.n()) as NodeId;
            if u == v {
                v = (v + 1) % g.n() as NodeId;
            }
            eng.do_swap(u, v);
        }
        let j = eng.objective();
        let epoch = eng.moves_epoch();
        let gammas: Vec<u64> = (0..g.n() as NodeId).map(|x| eng.gamma_of(x)).collect();
        let versions: Vec<u64> = (0..g.n() as NodeId).map(|x| eng.version_of(x)).collect();
        let warm = SwapEngine::from_warm(&g, &o, eng.into_warm_parts());
        assert_eq!(warm.objective(), j);
        assert_eq!(warm.moves_epoch(), epoch);
        for x in 0..g.n() as NodeId {
            assert_eq!(warm.gamma_of(x), gammas[x as usize], "gamma({x})");
            assert_eq!(warm.version_of(x), versions[x as usize], "version({x})");
        }
        assert!(warm.gamma_invariant_holds());
        assert_eq!(warm.objective(), warm.recompute_objective());
    }

    #[test]
    fn delta_patch_matches_fresh_engine_on_updated_graph() {
        use crate::graph::EdgeDelta;
        let (g, o) = setup(7, 52);
        let mut rng = Rng::new(53);
        let mut eng = SwapEngine::new(&g, &o, Mapping { sigma: rng.permutation(g.n()) });
        for _ in 0..60 {
            let u = rng.index(g.n()) as NodeId;
            let mut v = rng.index(g.n()) as NodeId;
            if u == v {
                v = (v + 1) % g.n() as NodeId;
            }
            eng.do_swap(u, v);
        }
        let parts = eng.into_warm_parts();
        // a mixed batch: existing-edge updates, one zero-out, one insert
        let a = 0 as NodeId;
        let b = g.neighbors(a)[0];
        let c = 1 as NodeId;
        let d = g.neighbors(c)[0];
        let mut far = 2 as NodeId; // endpoint pair guaranteed non-adjacent
        while g.edge_weight(far, (far + 5) % g.n() as NodeId).is_some()
            || far == (far + 5) % g.n() as NodeId
        {
            far += 1;
        }
        let mut g2 = g.clone();
        let out = g2
            .apply_deltas(&[
                EdgeDelta { u: a, v: b, w: g.edge_weight(a, b).unwrap() + 7 },
                EdgeDelta { u: c, v: d, w: 0 },
                EdgeDelta { u: far, v: (far + 5) % g.n() as NodeId, w: 9 },
            ])
            .unwrap();
        let mut warm = SwapEngine::from_warm(&g2, &o, parts);
        let versions_before: Vec<u64> =
            (0..g2.n() as NodeId).map(|x| warm.version_of(x)).collect();
        let epoch = warm.moves_epoch();
        warm.apply_deltas(&out.records);
        // bit-identical to a from-scratch engine on the updated graph
        let fresh = SwapEngine::new(&g2, &o, warm.mapping());
        assert_eq!(warm.objective(), fresh.objective());
        for x in 0..g2.n() as NodeId {
            assert_eq!(warm.gamma_of(x), fresh.gamma_of(x), "gamma({x})");
        }
        assert!(warm.gamma_invariant_holds());
        assert_eq!(warm.objective(), warm.recompute_objective());
        // only delta endpoints' versions bumped; epoch untouched
        assert_eq!(warm.moves_epoch(), epoch);
        for x in 0..g2.n() as NodeId {
            if out.touched.contains(&x) {
                assert!(warm.version_of(x) > versions_before[x as usize], "version({x})");
            } else {
                assert_eq!(warm.version_of(x), versions_before[x as usize], "version({x})");
            }
        }
    }

    #[test]
    fn dense_delta_patch_matches_rebuild() {
        use crate::graph::EdgeDelta;
        let (g, o) = setup(6, 54);
        let mut rng = Rng::new(55);
        let m = Mapping { sigma: rng.permutation(g.n()) };
        let mut dense = DenseEngine::new(&g, &o, m.clone());
        let a = 0 as NodeId;
        let b = g.neighbors(a)[0];
        let mut g2 = g.clone();
        let out = g2
            .apply_deltas(&[
                EdgeDelta { u: a, v: b, w: g.edge_weight(a, b).unwrap() + 3 },
                EdgeDelta { u: 10, v: 50, w: 4 },
            ])
            .unwrap();
        dense.apply_deltas(&out.records);
        let rebuilt = DenseEngine::new(&g2, &o, m);
        assert_eq!(dense.objective(), rebuilt.objective());
        assert_eq!(dense.objective(), dense.recompute_objective());
        // and the patched matrices keep agreeing with the sparse engine
        let mut sparse = SwapEngine::new(&g2, &o, dense.mapping());
        for _ in 0..50 {
            let u = rng.index(g2.n()) as NodeId;
            let mut v = rng.index(g2.n()) as NodeId;
            if u == v {
                v = (v + 1) % g2.n() as NodeId;
            }
            assert_eq!(sparse.swap_gain(u, v), dense.swap_gain(u, v), "gain ({u},{v})");
            sparse.do_swap(u, v);
            dense.do_swap(u, v);
            assert_eq!(sparse.objective(), dense.objective());
        }
    }

    #[test]
    fn dense_reset_matches_fresh_engine() {
        let (g, o) = setup(6, 22);
        let mut rng = Rng::new(23);
        let m1 = Mapping { sigma: rng.permutation(g.n()) };
        let m2 = Mapping { sigma: rng.permutation(g.n()) };
        let mut eng = DenseEngine::new(&g, &o, m1);
        eng.do_swap(0, 1);
        eng.reset(m2.clone());
        let fresh = DenseEngine::new(&g, &o, m2);
        assert_eq!(eng.objective(), fresh.objective());
        assert_eq!(eng.mapping(), fresh.mapping());
        assert_eq!(eng.swaps_applied, 0);
        assert_eq!(eng.n(), g.n());
    }
}
