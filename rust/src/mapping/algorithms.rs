//! Named end-to-end mapping algorithms: the specification registry.
//!
//! This registry is shared by the CLI, the coordinator service and the
//! benchmark harness, so every experiment refers to algorithms by the same
//! names the paper uses: `identity`, `random`, `mm` (Müller-Merbach), `gac`
//! (GreedyAllC), `rcb` (LibTopoMap-like), `bottomup`, `topdown`, with
//! optional `+N2`, `+Np`, `+Nc<d>`, `+NcCyc<d>`, `+gc:nc<d>`,
//! `+gc:nccyc<d>` local-search suffixes (`d >= 1`; e.g.
//! the paper's best trade-off `topdown+Nc10`) and an optional `ml:` prefix
//! selecting the multilevel V-cycle ([`crate::mapping::multilevel`]), e.g.
//! `ml:topdown+Nc5`: coarsen the communication graph, run the named
//! construction at the coarsest level, refine with the named neighborhood at
//! *every* level while uncoarsening.
//!
//! Execution lives in [`crate::api`]: build a [`crate::api::MapJobBuilder`]
//! with a spec from this registry and run it through a
//! [`crate::api::MapSession`]. (The former free function `run` — deprecated
//! since 0.2.0 — has been removed now that nothing links against it; see
//! DESIGN.md §2.)

use super::multilevel::LevelStat;
use super::objective::Mapping;
use super::refine::SearchStats;

/// Initial-solution algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Construction {
    Identity,
    Random,
    MuellerMerbach,
    GreedyAllC,
    TopDown,
    BottomUp,
    Rcb,
}

/// Local-search neighborhood (§2, §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Neighborhood {
    /// No local search.
    None,
    /// Heider's full pair exchange `N²`.
    N2,
    /// Brandfass et al.'s pruned index blocks `N_p` with this block length.
    Np { block_len: usize },
    /// This paper's communication-graph neighborhood `N_C^d`.
    Nc { d: u32 },
    /// `N_C^d` followed by triangle rotations (§5 future work, implemented
    /// in [`super::refine::Cycle3`]); runs under both gain engines through
    /// the [`super::refine::Swapper`] trait.
    NcCycle { d: u32 },
    /// The FM-style gain-cached `N_C^d` search (`gc:nc<d>`, implemented in
    /// [`super::refine::GainCacheNc`]): a priority bucket queue over the
    /// pair set with lazy move-version invalidation. Same neighborhood as
    /// [`Self::Nc`], but terminates at a provable local optimum, never
    /// consults the RNG, and skips re-evaluating pairs no move touched.
    GcNc { d: u32 },
    /// The unified move-class gain cache (`gc:nccyc<d>`): ONE queue holds
    /// the `N_C^d` pair swaps *and* both directions of every
    /// communication-graph triangle rotation, popping whichever move class
    /// currently has the best gain — unlike [`Self::NcCycle`], which parks
    /// every rotation behind pair-swap convergence. Terminates at a
    /// provable local optimum of the union neighborhood and, like
    /// [`Self::GcNc`], never consults the RNG.
    GcNcCycle { d: u32 },
}

/// Gain-computation mode: the paper's fast sparse engine or the dense
/// `O(n)`-per-swap baseline (Table 1's comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GainMode {
    Fast,
    SlowDense,
}

/// A full algorithm specification.
#[derive(Debug, Clone, Copy)]
pub struct AlgorithmSpec {
    pub construction: Construction,
    pub neighborhood: Neighborhood,
    pub gain_mode: GainMode,
    /// Max sweeps for the cyclic neighborhoods (safety bound).
    pub max_sweeps: usize,
    /// Run as a multilevel V-cycle (`ml:` prefix): the construction maps the
    /// coarsest graph, the neighborhood refines at every level. The V-cycle
    /// depth knobs live on [`crate::api::MapJobBuilder`]
    /// (`levels`/`coarsen_limit`); the gain mode is ignored — the V-cycle
    /// always drives the fast engine.
    pub multilevel: bool,
}

impl AlgorithmSpec {
    /// Construction-only spec.
    pub fn construction_only(c: Construction) -> AlgorithmSpec {
        AlgorithmSpec {
            construction: c,
            neighborhood: Neighborhood::None,
            gain_mode: GainMode::Fast,
            max_sweeps: 100,
            multilevel: false,
        }
    }

    /// Parse names like `topdown`, `mm+Np`, `topdown+Nc10`, `random+N2`,
    /// `ml:topdown+Nc5`.
    pub fn parse(name: &str) -> Result<AlgorithmSpec, String> {
        let (multilevel, rest) = match name.strip_prefix("ml:") {
            Some(rest) => (true, rest),
            None => (false, name),
        };
        let (cname, ls) = match rest.split_once('+') {
            Some((c, l)) => (c, Some(l)),
            None => (rest, None),
        };
        let construction = match cname {
            "identity" => Construction::Identity,
            "random" => Construction::Random,
            "mm" | "muellermerbach" => Construction::MuellerMerbach,
            "gac" | "greedyallc" => Construction::GreedyAllC,
            "topdown" | "td" => Construction::TopDown,
            "bottomup" | "bu" => Construction::BottomUp,
            "rcb" | "libtopomap" => Construction::Rcb,
            other => return Err(format!("unknown construction {other:?}")),
        };
        // shared distance parser for the d-parameterized neighborhoods:
        // d = 0 selects an empty neighborhood the grammar never defined
        // (`nc_pairs` used to hand back the d=1 edge set for it), so it is
        // rejected here rather than silently running the wrong pair set
        let parse_d = |s: &str, prefix: usize, what: &str| -> Result<u32, String> {
            let d: u32 = s[prefix..]
                .parse()
                .map_err(|e| format!("bad {what} distance {s:?}: {e}"))?;
            if d == 0 {
                return Err(format!(
                    "bad {what} distance {s:?}: d must be >= 1 (d=0 is the empty neighborhood)"
                ));
            }
            Ok(d)
        };
        let neighborhood = match ls {
            None => Neighborhood::None,
            Some("N2") | Some("n2") => Neighborhood::N2,
            Some("Np") | Some("np") => Neighborhood::Np { block_len: 64 },
            // gc:nccyc must match before its gc:nc prefix
            Some(s) if s.to_ascii_lowercase().starts_with("gc:nccyc") => {
                Neighborhood::GcNcCycle { d: parse_d(s, 8, "gc:nccyc")? }
            }
            Some(s) if s.to_ascii_lowercase().starts_with("gc:nc") => {
                Neighborhood::GcNc { d: parse_d(s, 5, "gc:nc")? }
            }
            Some(s) if s.to_ascii_lowercase().starts_with("nccyc") => {
                Neighborhood::NcCycle { d: parse_d(s, 5, "NcCyc")? }
            }
            Some(s) if s.to_ascii_lowercase().starts_with("nc") => {
                Neighborhood::Nc { d: parse_d(s, 2, "Nc")? }
            }
            Some(other) => return Err(format!("unknown neighborhood {other:?}")),
        };
        Ok(AlgorithmSpec {
            construction,
            neighborhood,
            gain_mode: GainMode::Fast,
            max_sweeps: 100,
            multilevel,
        })
    }

    /// Canonical name (inverse of [`Self::parse`]).
    pub fn name(&self) -> String {
        let c = match self.construction {
            Construction::Identity => "identity",
            Construction::Random => "random",
            Construction::MuellerMerbach => "mm",
            Construction::GreedyAllC => "gac",
            Construction::TopDown => "topdown",
            Construction::BottomUp => "bottomup",
            Construction::Rcb => "rcb",
        };
        let ml = if self.multilevel { "ml:" } else { "" };
        match self.neighborhood {
            Neighborhood::None => format!("{ml}{c}"),
            Neighborhood::N2 => format!("{ml}{c}+N2"),
            Neighborhood::Np { .. } => format!("{ml}{c}+Np"),
            Neighborhood::Nc { d } => format!("{ml}{c}+Nc{d}"),
            Neighborhood::NcCycle { d } => format!("{ml}{c}+NcCyc{d}"),
            Neighborhood::GcNc { d } => format!("{ml}{c}+gc:nc{d}"),
            Neighborhood::GcNcCycle { d } => format!("{ml}{c}+gc:nccyc{d}"),
        }
    }
}

/// Result of one end-to-end mapping run.
#[derive(Debug, Clone)]
pub struct MapResult {
    pub mapping: Mapping,
    /// Objective after construction (before local search). For multilevel
    /// runs: the coarsest construction projected to the finest level
    /// *without* refinement.
    pub objective_initial: u64,
    /// Final objective.
    pub objective: u64,
    /// Construction wall time (seconds).
    pub construct_secs: f64,
    /// Local-search wall time (seconds).
    pub ls_secs: f64,
    /// Local-search statistics (for multilevel runs: the aggregate over
    /// every level).
    pub stats: SearchStats,
    /// Per-level V-cycle statistics, coarsest first; empty for single-level
    /// runs.
    pub level_stats: Vec<LevelStat>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for name in ["identity", "random", "mm", "gac", "topdown", "bottomup", "rcb",
                     "topdown+Nc10", "mm+Np", "random+N2", "mm+Nc1", "topdown+NcCyc1",
                     "ml:topdown+Nc5", "ml:mm", "ml:bottomup+N2", "ml:rcb+NcCyc2",
                     "topdown+gc:nc10", "mm+gc:nc1", "ml:topdown+gc:nc5",
                     "topdown+gc:nccyc10", "mm+gc:nccyc1", "ml:topdown+gc:nccyc5"] {
            let spec = AlgorithmSpec::parse(name).unwrap();
            assert_eq!(spec.name(), *name, "roundtrip {name}");
        }
        assert!(AlgorithmSpec::parse("bogus").is_err());
        assert!(AlgorithmSpec::parse("mm+Nq3").is_err());
        assert!(AlgorithmSpec::parse("mm+Ncx").is_err());
        assert!(AlgorithmSpec::parse("ml:").is_err());
        assert!(AlgorithmSpec::parse("ml:bogus").is_err());
        assert!(AlgorithmSpec::parse("ml:ml:mm").is_err());
    }

    #[test]
    fn parse_name_roundtrip_every_combination() {
        // every construction × every neighborhood shape × flat/multilevel
        let constructions = [
            (Construction::Identity, "identity"),
            (Construction::Random, "random"),
            (Construction::MuellerMerbach, "mm"),
            (Construction::GreedyAllC, "gac"),
            (Construction::TopDown, "topdown"),
            (Construction::BottomUp, "bottomup"),
            (Construction::Rcb, "rcb"),
        ];
        let neighborhoods = [
            (Neighborhood::None, String::new()),
            (Neighborhood::N2, "+N2".to_string()),
            (Neighborhood::Np { block_len: 64 }, "+Np".to_string()),
            (Neighborhood::Nc { d: 1 }, "+Nc1".to_string()),
            (Neighborhood::Nc { d: 2 }, "+Nc2".to_string()),
            (Neighborhood::Nc { d: 10 }, "+Nc10".to_string()),
            (Neighborhood::Nc { d: 37 }, "+Nc37".to_string()),
            (Neighborhood::NcCycle { d: 1 }, "+NcCyc1".to_string()),
            (Neighborhood::NcCycle { d: 10 }, "+NcCyc10".to_string()),
            (Neighborhood::GcNc { d: 1 }, "+gc:nc1".to_string()),
            (Neighborhood::GcNc { d: 10 }, "+gc:nc10".to_string()),
            (Neighborhood::GcNcCycle { d: 1 }, "+gc:nccyc1".to_string()),
            (Neighborhood::GcNcCycle { d: 10 }, "+gc:nccyc10".to_string()),
        ];
        for ml in [false, true] {
            for (c, cname) in &constructions {
                for (nb, suffix) in &neighborhoods {
                    let name = format!("{}{cname}{suffix}", if ml { "ml:" } else { "" });
                    let spec = AlgorithmSpec::parse(&name)
                        .unwrap_or_else(|e| panic!("parsing {name:?}: {e}"));
                    assert_eq!(spec.construction, *c, "{name}");
                    assert_eq!(spec.neighborhood, *nb, "{name}");
                    assert_eq!(spec.gain_mode, GainMode::Fast, "{name}");
                    assert_eq!(spec.multilevel, ml, "{name}");
                    assert_eq!(spec.name(), name, "name() must invert parse()");
                    // name() output parses back to the same spec (idempotence)
                    let again = AlgorithmSpec::parse(&spec.name()).unwrap();
                    assert_eq!(again.name(), spec.name());
                }
            }
        }
    }

    #[test]
    fn parse_aliases_normalize() {
        for (alias, canonical) in [
            ("muellermerbach", "mm"),
            ("greedyallc", "gac"),
            ("td", "topdown"),
            ("bu", "bottomup"),
            ("libtopomap", "rcb"),
            ("mm+n2", "mm+N2"),
            ("mm+np", "mm+Np"),
            ("td+nc3", "topdown+Nc3"),
            ("td+NC3", "topdown+Nc3"),
            ("td+nccyc2", "topdown+NcCyc2"),
            ("td+NcCyc2", "topdown+NcCyc2"),
            ("td+GC:NC3", "topdown+gc:nc3"),
            ("td+Gc:Nc3", "topdown+gc:nc3"),
            ("td+GC:NCCYC3", "topdown+gc:nccyc3"),
            ("td+Gc:NcCyc3", "topdown+gc:nccyc3"),
            ("ml:td+nc5", "ml:topdown+Nc5"),
            ("ml:td+gc:nc5", "ml:topdown+gc:nc5"),
            ("ml:bu", "ml:bottomup"),
        ] {
            let spec = AlgorithmSpec::parse(alias).unwrap();
            assert_eq!(spec.name(), canonical, "alias {alias}");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "+N2",
            "mm+",
            "mm+Nq",
            "mm+Nq3",
            "mm+Nc",
            "mm+Ncx",
            "mm+Nc-1",
            "mm+Nc 1",
            "mm+NcCyc",
            "mm+NcCycx",
            "mm+NcCyc-2",
            "mm+gc:nc",
            "mm+gc:ncx",
            "mm+gc:nc-1",
            "mm+gc:",
            "mm+gc:Nq1",
            "mm+gc:nccyc",
            "mm+gc:nccycx",
            "mm+gc:nccyc-1",
            // d = 0 selects an empty neighborhood the grammar never
            // defined — rejected for every d-parameterized suffix
            "mm+Nc0",
            "mm+NcCyc0",
            "mm+gc:nc0",
            "mm+gc:nccyc0",
            "ml:mm+Nc0",
            "ml:mm+gc:nccyc0",
            "nope",
            "nope+Nc1",
            "MM",
            "mm+Nc1+Nc2",
            "ml:",
            "ml:+Nc1",
            "ml:nope",
            "ML:mm",
            "ml: mm",
            "ml:ml:topdown",
        ] {
            assert!(AlgorithmSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }
}
