//! Named end-to-end mapping algorithms: construction ⊕ local search.
//!
//! This registry is shared by the CLI, the coordinator service and the
//! benchmark harness, so every experiment in EXPERIMENTS.md refers to
//! algorithms by the same names the paper uses: `identity`, `random`, `mm`
//! (Müller-Merbach), `gac` (GreedyAllC), `rcb` (LibTopoMap-like),
//! `bottomup`, `topdown`, with optional `+N2`, `+Np`, `+Nc<d>` local-search
//! suffixes (e.g. the paper's best trade-off `topdown+Nc10`).

use super::construct;
use super::hierarchy::{DistanceOracle, Hierarchy};
use super::local_search::{cycle3_search, n2_cyclic, nc_neighborhood, np_blocks, SearchStats};
use super::objective::{DenseEngine, Mapping, SwapEngine};
use crate::graph::Graph;
use crate::partition::PartitionConfig;
use crate::util::{Rng, Timer};

/// Initial-solution algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Construction {
    Identity,
    Random,
    MuellerMerbach,
    GreedyAllC,
    TopDown,
    BottomUp,
    Rcb,
}

/// Local-search neighborhood (§2, §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Neighborhood {
    /// No local search.
    None,
    /// Heider's full pair exchange `N²`.
    N2,
    /// Brandfass et al.'s pruned index blocks `N_p` with this block length.
    Np { block_len: usize },
    /// This paper's communication-graph neighborhood `N_C^d`.
    Nc { d: u32 },
    /// `N_C^d` followed by triangle rotations (§5 future work, implemented
    /// in [`super::local_search::cycle3_search`]). Fast engine only.
    NcCycle { d: u32 },
}

/// Gain-computation mode: the paper's fast sparse engine or the dense
/// `O(n)`-per-swap baseline (Table 1's comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GainMode {
    Fast,
    SlowDense,
}

/// A full algorithm specification.
#[derive(Debug, Clone, Copy)]
pub struct AlgorithmSpec {
    pub construction: Construction,
    pub neighborhood: Neighborhood,
    pub gain_mode: GainMode,
    /// Max sweeps for the cyclic neighborhoods (safety bound).
    pub max_sweeps: usize,
}

impl AlgorithmSpec {
    /// Construction-only spec.
    pub fn construction_only(c: Construction) -> AlgorithmSpec {
        AlgorithmSpec {
            construction: c,
            neighborhood: Neighborhood::None,
            gain_mode: GainMode::Fast,
            max_sweeps: 100,
        }
    }

    /// Parse names like `topdown`, `mm+Np`, `topdown+Nc10`, `random+N2`.
    pub fn parse(name: &str) -> Result<AlgorithmSpec, String> {
        let (cname, ls) = match name.split_once('+') {
            Some((c, l)) => (c, Some(l)),
            None => (name, None),
        };
        let construction = match cname {
            "identity" => Construction::Identity,
            "random" => Construction::Random,
            "mm" | "muellermerbach" => Construction::MuellerMerbach,
            "gac" | "greedyallc" => Construction::GreedyAllC,
            "topdown" | "td" => Construction::TopDown,
            "bottomup" | "bu" => Construction::BottomUp,
            "rcb" | "libtopomap" => Construction::Rcb,
            other => return Err(format!("unknown construction {other:?}")),
        };
        let neighborhood = match ls {
            None => Neighborhood::None,
            Some("N2") | Some("n2") => Neighborhood::N2,
            Some("Np") | Some("np") => Neighborhood::Np { block_len: 64 },
            Some(s) if s.to_ascii_lowercase().starts_with("nccyc") => {
                let d: u32 = s[5..]
                    .parse()
                    .map_err(|e| format!("bad NcCyc distance {s:?}: {e}"))?;
                Neighborhood::NcCycle { d }
            }
            Some(s) if s.to_ascii_lowercase().starts_with("nc") => {
                let d: u32 = s[2..]
                    .parse()
                    .map_err(|e| format!("bad Nc distance {s:?}: {e}"))?;
                Neighborhood::Nc { d }
            }
            Some(other) => return Err(format!("unknown neighborhood {other:?}")),
        };
        Ok(AlgorithmSpec {
            construction,
            neighborhood,
            gain_mode: GainMode::Fast,
            max_sweeps: 100,
        })
    }

    /// Canonical name (inverse of [`Self::parse`]).
    pub fn name(&self) -> String {
        let c = match self.construction {
            Construction::Identity => "identity",
            Construction::Random => "random",
            Construction::MuellerMerbach => "mm",
            Construction::GreedyAllC => "gac",
            Construction::TopDown => "topdown",
            Construction::BottomUp => "bottomup",
            Construction::Rcb => "rcb",
        };
        match self.neighborhood {
            Neighborhood::None => c.to_string(),
            Neighborhood::N2 => format!("{c}+N2"),
            Neighborhood::Np { .. } => format!("{c}+Np"),
            Neighborhood::Nc { d } => format!("{c}+Nc{d}"),
            Neighborhood::NcCycle { d } => format!("{c}+NcCyc{d}"),
        }
    }
}

/// Result of one end-to-end mapping run.
#[derive(Debug, Clone)]
pub struct MapResult {
    pub mapping: Mapping,
    /// Objective after construction (before local search).
    pub objective_initial: u64,
    /// Final objective.
    pub objective: u64,
    /// Construction wall time (seconds).
    pub construct_secs: f64,
    /// Local-search wall time (seconds).
    pub ls_secs: f64,
    /// Local-search statistics.
    pub stats: SearchStats,
}

/// Run a complete algorithm on a communication graph + hierarchy.
pub fn run(
    comm: &Graph,
    hierarchy: &Hierarchy,
    oracle: &DistanceOracle,
    spec: &AlgorithmSpec,
    part_cfg: &PartitionConfig,
    rng: &mut Rng,
) -> MapResult {
    let t = Timer::start();
    let mapping = match spec.construction {
        Construction::Identity => construct::identity(comm.n()),
        Construction::Random => construct::random(comm.n(), rng),
        Construction::MuellerMerbach => construct::mueller_merbach(comm, oracle),
        Construction::GreedyAllC => construct::greedy_all_c(comm, hierarchy),
        Construction::TopDown => construct::top_down(comm, hierarchy, part_cfg, rng),
        Construction::BottomUp => construct::bottom_up(comm, hierarchy, part_cfg, rng),
        Construction::Rcb => construct::rcb(comm, part_cfg, rng),
    };
    let construct_secs = t.secs();

    let t = Timer::start();
    let (mapping, objective_initial, objective, stats) = match spec.gain_mode {
        GainMode::Fast => {
            let mut eng = SwapEngine::new(comm, oracle, mapping);
            let j0 = eng.objective();
            let stats = run_ls(&mut eng, comm, hierarchy, spec, rng);
            (eng.mapping(), j0, eng.objective(), stats)
        }
        GainMode::SlowDense => {
            let mut eng = DenseEngine::new(comm, oracle, mapping);
            let j0 = eng.objective();
            let stats = run_ls_dense(&mut eng, comm, hierarchy, spec, rng);
            (eng.mapping(), j0, eng.objective(), stats)
        }
    };
    let ls_secs = t.secs();

    MapResult { mapping, objective_initial, objective, construct_secs, ls_secs, stats }
}

fn run_ls(
    eng: &mut SwapEngine,
    comm: &Graph,
    h: &Hierarchy,
    spec: &AlgorithmSpec,
    rng: &mut Rng,
) -> SearchStats {
    match spec.neighborhood {
        Neighborhood::None => SearchStats::default(),
        Neighborhood::N2 => n2_cyclic(eng, comm.n(), spec.max_sweeps),
        Neighborhood::Np { block_len } => {
            np_blocks(eng, comm.n(), block_len, Some(h), |e, u| e.pe_of(u), spec.max_sweeps)
        }
        Neighborhood::Nc { d } => nc_neighborhood(eng, comm, d, rng, u64::MAX),
        Neighborhood::NcCycle { d } => {
            let mut stats = nc_neighborhood(eng, comm, d, rng, u64::MAX);
            let cyc = cycle3_search(eng, comm, rng, spec.max_sweeps);
            stats.evaluated += cyc.evaluated;
            stats.improved += cyc.improved;
            stats.rounds += cyc.rounds;
            stats
        }
    }
}

fn run_ls_dense(
    eng: &mut DenseEngine,
    comm: &Graph,
    h: &Hierarchy,
    spec: &AlgorithmSpec,
    rng: &mut Rng,
) -> SearchStats {
    match spec.neighborhood {
        Neighborhood::None => SearchStats::default(),
        Neighborhood::N2 => n2_cyclic(eng, comm.n(), spec.max_sweeps),
        Neighborhood::Np { block_len } => np_blocks(
            eng,
            comm.n(),
            block_len,
            Some(h),
            |e, u| e.mapping().sigma[u as usize],
            spec.max_sweeps,
        ),
        Neighborhood::Nc { d } => nc_neighborhood(eng, comm, d, rng, u64::MAX),
        // rotations need the Γ machinery of the fast engine; the dense
        // baseline (Table 1 only) runs the pair-swap part alone
        Neighborhood::NcCycle { d } => nc_neighborhood(eng, comm, d, rng, u64::MAX),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;

    #[test]
    fn parse_roundtrip() {
        for name in ["identity", "random", "mm", "gac", "topdown", "bottomup", "rcb",
                     "topdown+Nc10", "mm+Np", "random+N2", "mm+Nc1", "topdown+NcCyc1"] {
            let spec = AlgorithmSpec::parse(name).unwrap();
            assert_eq!(spec.name(), *name, "roundtrip {name}");
        }
        assert!(AlgorithmSpec::parse("bogus").is_err());
        assert!(AlgorithmSpec::parse("mm+Nq3").is_err());
        assert!(AlgorithmSpec::parse("mm+Ncx").is_err());
    }

    #[test]
    fn run_end_to_end_improves() {
        let mut rng = Rng::new(1);
        let g = random_geometric_graph(256, &mut rng);
        let h = Hierarchy::new(vec![4, 16, 4], vec![1, 10, 100]).unwrap();
        let o = DistanceOracle::implicit(h.clone());
        let spec = AlgorithmSpec::parse("mm+Nc2").unwrap();
        let r = run(&g, &h, &o, &spec, &PartitionConfig::fast(), &mut rng);
        r.mapping.validate().unwrap();
        assert!(r.objective <= r.objective_initial);
        assert!(r.stats.evaluated > 0);
    }

    #[test]
    fn slow_and_fast_same_final_objective() {
        let mut rng = Rng::new(2);
        let g = random_geometric_graph(128, &mut rng);
        let h = Hierarchy::new(vec![4, 16, 2], vec![1, 10, 100]).unwrap();
        let o = DistanceOracle::implicit(h.clone());
        let mut spec = AlgorithmSpec::parse("mm+Np").unwrap();
        let mut r1 = Rng::new(3);
        let fast = run(&g, &h, &o, &spec, &PartitionConfig::fast(), &mut r1);
        spec.gain_mode = GainMode::SlowDense;
        let mut r2 = Rng::new(3);
        let slow = run(&g, &h, &o, &spec, &PartitionConfig::fast(), &mut r2);
        assert_eq!(fast.objective, slow.objective);
        assert_eq!(fast.mapping.sigma, slow.mapping.sigma);
    }
}
