//! Named end-to-end mapping algorithms: the specification registry.
//!
//! This registry is shared by the CLI, the coordinator service and the
//! benchmark harness, so every experiment refers to algorithms by the same
//! names the paper uses: `identity`, `random`, `mm` (Müller-Merbach), `gac`
//! (GreedyAllC), `rcb` (LibTopoMap-like), `bottomup`, `topdown`, with
//! optional `+N2`, `+Np`, `+Nc<d>`, `+NcCyc<d>` local-search suffixes (e.g.
//! the paper's best trade-off `topdown+Nc10`).
//!
//! Execution lives in [`crate::api`]: build a [`crate::api::MapJobBuilder`]
//! with a spec from this registry and run it through a
//! [`crate::api::MapSession`]. The free function [`run`] survives only as a
//! deprecated single-repetition shim.

use super::hierarchy::{DistanceOracle, Hierarchy};
use super::local_search::SearchStats;
use super::objective::Mapping;
use crate::graph::Graph;
use crate::partition::PartitionConfig;
use crate::util::Rng;

/// Initial-solution algorithm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Construction {
    Identity,
    Random,
    MuellerMerbach,
    GreedyAllC,
    TopDown,
    BottomUp,
    Rcb,
}

/// Local-search neighborhood (§2, §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Neighborhood {
    /// No local search.
    None,
    /// Heider's full pair exchange `N²`.
    N2,
    /// Brandfass et al.'s pruned index blocks `N_p` with this block length.
    Np { block_len: usize },
    /// This paper's communication-graph neighborhood `N_C^d`.
    Nc { d: u32 },
    /// `N_C^d` followed by triangle rotations (§5 future work, implemented
    /// in [`super::local_search::cycle3_search`]). Fast engine only.
    NcCycle { d: u32 },
}

/// Gain-computation mode: the paper's fast sparse engine or the dense
/// `O(n)`-per-swap baseline (Table 1's comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GainMode {
    Fast,
    SlowDense,
}

/// A full algorithm specification.
#[derive(Debug, Clone, Copy)]
pub struct AlgorithmSpec {
    pub construction: Construction,
    pub neighborhood: Neighborhood,
    pub gain_mode: GainMode,
    /// Max sweeps for the cyclic neighborhoods (safety bound).
    pub max_sweeps: usize,
}

impl AlgorithmSpec {
    /// Construction-only spec.
    pub fn construction_only(c: Construction) -> AlgorithmSpec {
        AlgorithmSpec {
            construction: c,
            neighborhood: Neighborhood::None,
            gain_mode: GainMode::Fast,
            max_sweeps: 100,
        }
    }

    /// Parse names like `topdown`, `mm+Np`, `topdown+Nc10`, `random+N2`.
    pub fn parse(name: &str) -> Result<AlgorithmSpec, String> {
        let (cname, ls) = match name.split_once('+') {
            Some((c, l)) => (c, Some(l)),
            None => (name, None),
        };
        let construction = match cname {
            "identity" => Construction::Identity,
            "random" => Construction::Random,
            "mm" | "muellermerbach" => Construction::MuellerMerbach,
            "gac" | "greedyallc" => Construction::GreedyAllC,
            "topdown" | "td" => Construction::TopDown,
            "bottomup" | "bu" => Construction::BottomUp,
            "rcb" | "libtopomap" => Construction::Rcb,
            other => return Err(format!("unknown construction {other:?}")),
        };
        let neighborhood = match ls {
            None => Neighborhood::None,
            Some("N2") | Some("n2") => Neighborhood::N2,
            Some("Np") | Some("np") => Neighborhood::Np { block_len: 64 },
            Some(s) if s.to_ascii_lowercase().starts_with("nccyc") => {
                let d: u32 = s[5..]
                    .parse()
                    .map_err(|e| format!("bad NcCyc distance {s:?}: {e}"))?;
                Neighborhood::NcCycle { d }
            }
            Some(s) if s.to_ascii_lowercase().starts_with("nc") => {
                let d: u32 = s[2..]
                    .parse()
                    .map_err(|e| format!("bad Nc distance {s:?}: {e}"))?;
                Neighborhood::Nc { d }
            }
            Some(other) => return Err(format!("unknown neighborhood {other:?}")),
        };
        Ok(AlgorithmSpec {
            construction,
            neighborhood,
            gain_mode: GainMode::Fast,
            max_sweeps: 100,
        })
    }

    /// Canonical name (inverse of [`Self::parse`]).
    pub fn name(&self) -> String {
        let c = match self.construction {
            Construction::Identity => "identity",
            Construction::Random => "random",
            Construction::MuellerMerbach => "mm",
            Construction::GreedyAllC => "gac",
            Construction::TopDown => "topdown",
            Construction::BottomUp => "bottomup",
            Construction::Rcb => "rcb",
        };
        match self.neighborhood {
            Neighborhood::None => c.to_string(),
            Neighborhood::N2 => format!("{c}+N2"),
            Neighborhood::Np { .. } => format!("{c}+Np"),
            Neighborhood::Nc { d } => format!("{c}+Nc{d}"),
            Neighborhood::NcCycle { d } => format!("{c}+NcCyc{d}"),
        }
    }
}

/// Result of one end-to-end mapping run.
#[derive(Debug, Clone)]
pub struct MapResult {
    pub mapping: Mapping,
    /// Objective after construction (before local search).
    pub objective_initial: u64,
    /// Final objective.
    pub objective: u64,
    /// Construction wall time (seconds).
    pub construct_secs: f64,
    /// Local-search wall time (seconds).
    pub ls_secs: f64,
    /// Local-search statistics.
    pub stats: SearchStats,
}

/// Run a complete algorithm on a communication graph + hierarchy, once.
///
/// Deprecated: this free function forces every caller to hand-roll oracle
/// construction, repetition loops and best-of-N selection. Use
/// [`crate::api::MapJobBuilder`] + [`crate::api::MapSession`] instead, which
/// also reuse engine scratch, pair sets and deterministic constructions
/// across repetitions. This shim executes a single repetition through the
/// same session machinery (with throwaway scratch), so trajectories are
/// bit-identical to the pre-api behavior for a given RNG.
#[deprecated(
    since = "0.2.0",
    note = "use api::MapJobBuilder + api::MapSession (this shim runs one repetition with no scratch reuse)"
)]
pub fn run(
    comm: &Graph,
    hierarchy: &Hierarchy,
    oracle: &DistanceOracle,
    spec: &AlgorithmSpec,
    part_cfg: &PartitionConfig,
    rng: &mut Rng,
) -> MapResult {
    crate::api::session::execute_once(
        comm,
        hierarchy,
        oracle,
        spec,
        part_cfg,
        rng,
        &mut Default::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;

    #[test]
    fn parse_roundtrip() {
        for name in ["identity", "random", "mm", "gac", "topdown", "bottomup", "rcb",
                     "topdown+Nc10", "mm+Np", "random+N2", "mm+Nc1", "topdown+NcCyc1"] {
            let spec = AlgorithmSpec::parse(name).unwrap();
            assert_eq!(spec.name(), *name, "roundtrip {name}");
        }
        assert!(AlgorithmSpec::parse("bogus").is_err());
        assert!(AlgorithmSpec::parse("mm+Nq3").is_err());
        assert!(AlgorithmSpec::parse("mm+Ncx").is_err());
    }

    #[test]
    fn parse_name_roundtrip_every_combination() {
        // every construction × every neighborhood shape (including NcCyc<d>)
        let constructions = [
            (Construction::Identity, "identity"),
            (Construction::Random, "random"),
            (Construction::MuellerMerbach, "mm"),
            (Construction::GreedyAllC, "gac"),
            (Construction::TopDown, "topdown"),
            (Construction::BottomUp, "bottomup"),
            (Construction::Rcb, "rcb"),
        ];
        let neighborhoods = [
            (Neighborhood::None, String::new()),
            (Neighborhood::N2, "+N2".to_string()),
            (Neighborhood::Np { block_len: 64 }, "+Np".to_string()),
            (Neighborhood::Nc { d: 1 }, "+Nc1".to_string()),
            (Neighborhood::Nc { d: 2 }, "+Nc2".to_string()),
            (Neighborhood::Nc { d: 10 }, "+Nc10".to_string()),
            (Neighborhood::Nc { d: 37 }, "+Nc37".to_string()),
            (Neighborhood::NcCycle { d: 1 }, "+NcCyc1".to_string()),
            (Neighborhood::NcCycle { d: 10 }, "+NcCyc10".to_string()),
        ];
        for (c, cname) in &constructions {
            for (nb, suffix) in &neighborhoods {
                let name = format!("{cname}{suffix}");
                let spec = AlgorithmSpec::parse(&name)
                    .unwrap_or_else(|e| panic!("parsing {name:?}: {e}"));
                assert_eq!(spec.construction, *c, "{name}");
                assert_eq!(spec.neighborhood, *nb, "{name}");
                assert_eq!(spec.gain_mode, GainMode::Fast, "{name}");
                assert_eq!(spec.name(), name, "name() must invert parse()");
                // name() output parses back to the same spec (idempotence)
                let again = AlgorithmSpec::parse(&spec.name()).unwrap();
                assert_eq!(again.name(), spec.name());
            }
        }
    }

    #[test]
    fn parse_aliases_normalize() {
        for (alias, canonical) in [
            ("muellermerbach", "mm"),
            ("greedyallc", "gac"),
            ("td", "topdown"),
            ("bu", "bottomup"),
            ("libtopomap", "rcb"),
            ("mm+n2", "mm+N2"),
            ("mm+np", "mm+Np"),
            ("td+nc3", "topdown+Nc3"),
            ("td+NC3", "topdown+Nc3"),
            ("td+nccyc2", "topdown+NcCyc2"),
            ("td+NcCyc2", "topdown+NcCyc2"),
        ] {
            let spec = AlgorithmSpec::parse(alias).unwrap();
            assert_eq!(spec.name(), canonical, "alias {alias}");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "+N2",
            "mm+",
            "mm+Nq",
            "mm+Nq3",
            "mm+Nc",
            "mm+Ncx",
            "mm+Nc-1",
            "mm+Nc 1",
            "mm+NcCyc",
            "mm+NcCycx",
            "mm+NcCyc-2",
            "nope",
            "nope+Nc1",
            "MM",
            "mm+Nc1+Nc2",
        ] {
            assert!(AlgorithmSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_run_shim_end_to_end_improves() {
        let mut rng = Rng::new(1);
        let g = random_geometric_graph(256, &mut rng);
        let h = Hierarchy::new(vec![4, 16, 4], vec![1, 10, 100]).unwrap();
        let o = DistanceOracle::implicit(h.clone());
        let spec = AlgorithmSpec::parse("mm+Nc2").unwrap();
        let r = run(&g, &h, &o, &spec, &PartitionConfig::fast(), &mut rng);
        r.mapping.validate().unwrap();
        assert!(r.objective <= r.objective_initial);
        assert!(r.stats.evaluated > 0);
    }

    #[test]
    #[allow(deprecated)]
    fn slow_and_fast_same_final_objective() {
        let mut rng = Rng::new(2);
        let g = random_geometric_graph(128, &mut rng);
        let h = Hierarchy::new(vec![4, 16, 2], vec![1, 10, 100]).unwrap();
        let o = DistanceOracle::implicit(h.clone());
        let mut spec = AlgorithmSpec::parse("mm+Np").unwrap();
        let mut r1 = Rng::new(3);
        let fast = run(&g, &h, &o, &spec, &PartitionConfig::fast(), &mut r1);
        spec.gain_mode = GainMode::SlowDense;
        let mut r2 = Rng::new(3);
        let slow = run(&g, &h, &o, &spec, &PartitionConfig::fast(), &mut r2);
        assert_eq!(fast.objective, slow.objective);
        assert_eq!(fast.mapping.sigma, slow.mapping.sigma);
    }
}
