//! Initial-mapping construction algorithms (paper §3.1 + all baselines).
//!
//! * [`identity`], [`random`] — trivial baselines (Figure 3).
//! * [`mueller_merbach`] — the classic greedy of Müller-Merbach [19]:
//!   repeatedly assign the unassigned process with the largest communication
//!   sum (to already-assigned processes) to the unassigned PE with the
//!   smallest distance sum (to already-assigned PEs). `O(n²)`.
//! * [`greedy_all_c`] — GreedyAllC of Glantz et al. [12]: links the two
//!   choices by scaling distances with the communication to be done, i.e.
//!   the PE minimizing the actual objective increase is chosen.
//! * [`top_down`] — this paper's multilevel construction: recursively split
//!   the communication graph along the hierarchy `a_k, a_{k-1}, …` with
//!   perfectly balanced partitions; blocks map to contiguous PE ranges.
//! * [`bottom_up`] — the dual: partition into blocks of `a_1`, contract,
//!   repeat up the hierarchy, then unwind to place blocks.
//! * [`rcb`] — dual recursive bisection à la LibTopoMap [15] (the paper's
//!   external comparison): simultaneously bisect process set and PE range.

use super::algorithms::Construction;
use crate::graph::{contract, induced_subgraph, Graph, NodeId, Weight};
use crate::model::topology::{Hierarchy, Machine, SubsystemTree, Topology};
use crate::partition::kway::{bisect_multilevel, exact_block_sizes, partition_exact_sizes};
use crate::partition::{partition_kway, PartitionConfig};
use crate::util::Rng;

use super::objective::Mapping;

/// Dispatch a [`Construction`] by name — the single §3.1 entry point shared
/// by the session execution path and the multilevel V-cycle (which runs it
/// on the *coarsest* graph against the folded machine). `machine` is the
/// structural model the recursive constructions split along; `oracle` is
/// the (possibly memoized-explicit) distance source greedy constructions
/// query — the session passes its cached oracle here.
///
/// Non-hierarchical machines reuse the registry through their natural
/// counterparts: Top-Down / Bottom-Up multisect non-uniform subsystem trees
/// along the tree itself ([`top_down_tree`] / [`bottom_up_tree`] — unequal
/// child blocks via exact-size partitions) and grids/tori along their
/// dimensions (the [`recursion_levels`] pseudo-hierarchy — the recursions
/// only consume fan-outs and contiguous PE ranges, which row-major grid
/// slabs are), and GreedyAllC runs its direct oracle-driven form
/// ([`greedy_all_c_generic`], the setting it was designed for in [12]).
pub fn initial(
    comm: &Graph,
    machine: &Machine,
    oracle: &Machine,
    construction: Construction,
    part_cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Mapping {
    match construction {
        Construction::Identity => identity(comm.n()),
        Construction::Random => random(comm.n(), rng),
        Construction::MuellerMerbach => mueller_merbach(comm, oracle),
        Construction::GreedyAllC => match machine.hier() {
            Some(h) => greedy_all_c(comm, h),
            None => greedy_all_c_generic(comm, oracle),
        },
        Construction::TopDown => match machine.tree() {
            Some(t) => top_down_tree(comm, t, part_cfg, rng),
            None => top_down(comm, &recursion_levels(machine), part_cfg, rng),
        },
        Construction::BottomUp => match machine.tree() {
            Some(t) => bottom_up_tree(comm, t, part_cfg, rng),
            None => bottom_up(comm, &recursion_levels(machine), part_cfg, rng),
        },
        Construction::Rcb => rcb(comm, part_cfg, rng),
    }
}

/// The level structure Top-Down / Bottom-Up recurse over, as a hierarchy:
/// the machine itself when hierarchical; for grids and tori, a
/// pseudo-hierarchy whose fan-outs are the dimension extents (innermost
/// first) — the recursions only use fan-outs, subsystem sizes and
/// contiguous PE ranges, and a row-major grid slab *is* a contiguous PE
/// range, so this is exactly dimension-wise multisection. Explicit
/// machines degrade to a single flat level (no structure to split along —
/// prefer `mm`/`gac` there).
fn recursion_levels(machine: &Machine) -> Hierarchy {
    let dims = match machine {
        Machine::Hier(h) => return h.clone(),
        Machine::Grid(g) => g.dims().to_vec(),
        Machine::Torus(t) => t.dims().to_vec(),
        // subsystem trees are routed to the dedicated tree recursions by
        // `initial`; a direct call degrades like an explicit machine
        Machine::Tree(_) | Machine::Explicit(_) => vec![machine.n_pes() as u64],
    };
    // distances are never consulted by the recursions; any non-decreasing
    // placeholder satisfies the Hierarchy constructor
    let d: Vec<u64> = (1..=dims.len() as u64).collect();
    Hierarchy::new(dims, d).expect("positive dims form a valid pseudo-hierarchy")
}

/// The identity assignment (process `i` on PE `i`). Surprisingly strong for
/// powers of two because the upstream KaHIP-style pipeline assigns
/// consecutive block ids by recursive bisection (§4.1).
pub fn identity(n: usize) -> Mapping {
    Mapping::identity(n)
}

/// Uniformly random assignment.
pub fn random(n: usize, rng: &mut Rng) -> Mapping {
    Mapping { sigma: rng.permutation(n) }
}

/// Müller-Merbach greedy construction [19]. `O(n²)` time, `O(n)` memory
/// beyond the oracle (distance sums are maintained incrementally; with an
/// explicit oracle this reproduces the original exactly, with the implicit
/// oracle it is the "online distances" variant of the scalability study).
pub fn mueller_merbach(comm: &Graph, oracle: &Machine) -> Mapping {
    let n = comm.n();
    assert_eq!(n, oracle.n_pes(), "processes ({n}) != PEs ({})", oracle.n_pes());
    let mut sigma = vec![u32::MAX; n];
    if n == 0 {
        return Mapping { sigma };
    }
    let mut proc_assigned = vec![false; n];
    let mut pe_used = vec![false; n];
    // communication of each unassigned process to assigned ones
    let mut comm_to_assigned = vec![0u64; n];
    // total communication volume (static tie-break / first pick)
    let volume: Vec<u64> = (0..n as NodeId)
        .map(|u| comm.edges(u).map(|(_, w)| w).sum())
        .collect();
    // distance of each unassigned PE to the used ones
    let mut dist_to_used = vec![0u64; n];

    for step in 0..n {
        // pick process: max comm-to-assigned, tie-break max volume, then id
        let mut best_u = usize::MAX;
        for u in 0..n {
            if proc_assigned[u] {
                continue;
            }
            if best_u == usize::MAX
                || comm_to_assigned[u] > comm_to_assigned[best_u]
                || (comm_to_assigned[u] == comm_to_assigned[best_u] && volume[u] > volume[best_u])
            {
                best_u = u;
            }
        }
        // pick PE: min distance sum to used PEs (ties: lowest id)
        let mut best_p = usize::MAX;
        for p in 0..n {
            if pe_used[p] {
                continue;
            }
            if best_p == usize::MAX || dist_to_used[p] < dist_to_used[best_p] {
                best_p = p;
            }
        }
        debug_assert!(best_u != usize::MAX && best_p != usize::MAX);
        sigma[best_u] = best_p as u32;
        proc_assigned[best_u] = true;
        pe_used[best_p] = true;
        // incremental updates — O(d_u) for comm, O(n) for distances
        for (x, w) in comm.edges(best_u as NodeId) {
            comm_to_assigned[x as usize] += w;
        }
        if step + 1 < n {
            for q in 0..n {
                if !pe_used[q] {
                    dist_to_used[q] += oracle.distance(q as u32, best_p as u32);
                }
            }
        }
    }
    Mapping { sigma }
}

/// GreedyAllC [12]: same process selection as Müller-Merbach, but the PE is
/// chosen to minimize the *objective increase*
/// `Σ_{assigned neighbor x} C[u][x] · D[q][σ(x)]`. With a hierarchical
/// oracle the inner sum is bucketed per hierarchy level, so each step costs
/// `O(d_u·k + n·k)` instead of `O(n·d_u)`.
///
/// Reproduction note (`benches/fig3.rs`): on *ultrametric* distances —
/// a homogeneous hierarchy, as in all of the paper's experiments — with
/// deterministic lowest-id tie-breaking, GreedyAllC provably coincides with
/// Müller-Merbach: PEs fill contiguously, so at any time only one subsystem
/// per level is partially filled, and both selection criteria (unweighted
/// distance sum vs. communication-scaled distance sum) choose inside it.
/// This matches the paper's observation that GreedyAllC "only improves
/// slightly, i.e., 1% on average" (the residual 1% stems from different
/// tie-breaking in the original binary). On non-ultrametric D (grids/tori,
/// the setting GreedyAllC was designed for in [12]) the two differ.
pub fn greedy_all_c(comm: &Graph, hierarchy: &Hierarchy) -> Mapping {
    let n = comm.n();
    assert_eq!(n, hierarchy.n_pes());
    let mut sigma = vec![u32::MAX; n];
    if n == 0 {
        return Mapping { sigma };
    }
    let levels = hierarchy.levels();
    let mut proc_assigned = vec![false; n];
    let mut pe_used = vec![false; n];
    let mut comm_to_assigned = vec![0u64; n];
    let volume: Vec<u64> = (0..n as NodeId)
        .map(|u| comm.edges(u).map(|(_, w)| w).sum())
        .collect();
    // per-level group -> communication sum of u's assigned neighbors there
    let mut group_sum: Vec<std::collections::HashMap<u64, u64>> =
        vec![std::collections::HashMap::new(); levels];

    for _ in 0..n {
        let mut best_u = usize::MAX;
        for u in 0..n {
            if proc_assigned[u] {
                continue;
            }
            if best_u == usize::MAX
                || comm_to_assigned[u] > comm_to_assigned[best_u]
                || (comm_to_assigned[u] == comm_to_assigned[best_u] && volume[u] > volume[best_u])
            {
                best_u = u;
            }
        }
        let u = best_u;
        // bucket u's assigned neighbors by the PE-group at every level
        for gs in group_sum.iter_mut() {
            gs.clear();
        }
        let mut total = 0u64;
        for (x, c) in comm.edges(u as NodeId) {
            if !proc_assigned[x as usize] {
                continue;
            }
            let px = sigma[x as usize] as u64;
            total += c;
            for (i, gs) in group_sum.iter_mut().enumerate() {
                *gs.entry(px / hierarchy.subsystem_size(i + 1)).or_insert(0) += c;
            }
        }
        // pick PE minimizing Σ_i d_i (A_i - A_{i-1}); A_{levels-1} == total
        let mut best_p = usize::MAX;
        let mut best_cost = u64::MAX;
        for q in 0..n {
            if pe_used[q] {
                continue;
            }
            let mut cost = 0u64;
            let mut prev = 0u64;
            for i in 0..levels {
                let a_i = *group_sum[i]
                    .get(&(q as u64 / hierarchy.subsystem_size(i + 1)))
                    .unwrap_or(&0);
                cost += hierarchy.d[i] * (a_i - prev);
                prev = a_i;
            }
            debug_assert_eq!(prev, total, "top level group must cover all neighbors");
            if cost < best_cost {
                best_cost = cost;
                best_p = q;
            }
        }
        sigma[u] = best_p as u32;
        proc_assigned[u] = true;
        pe_used[best_p] = true;
        for (x, w) in comm.edges(u as NodeId) {
            comm_to_assigned[x as usize] += w;
        }
    }
    Mapping { sigma }
}

/// GreedyAllC in its direct, oracle-driven form: identical selection rules
/// to [`greedy_all_c`], but the candidate cost `Σ_{assigned neighbor x}
/// C[u][x] · D[q][σ(x)]` is summed per free PE straight from the distance
/// oracle instead of being bucketed per hierarchy level — `O(n · d_u)` per
/// step instead of `O(d_u·k + n·k)`. This is the form Glantz et al. [12]
/// define for *non-ultrametric* machines (grids, tori); on a hierarchy the
/// two provably coincide (same cost function, same lowest-id tie-breaks —
/// regression-tested below).
pub fn greedy_all_c_generic(comm: &Graph, oracle: &Machine) -> Mapping {
    let n = comm.n();
    assert_eq!(n, oracle.n_pes(), "processes ({n}) != PEs ({})", oracle.n_pes());
    let mut sigma = vec![u32::MAX; n];
    if n == 0 {
        return Mapping { sigma };
    }
    let mut proc_assigned = vec![false; n];
    let mut pe_used = vec![false; n];
    let mut comm_to_assigned = vec![0u64; n];
    let volume: Vec<u64> = (0..n as NodeId)
        .map(|u| comm.edges(u).map(|(_, w)| w).sum())
        .collect();
    let mut placed: Vec<(u32, u64)> = Vec::new(); // (PE of neighbor, weight)

    for _ in 0..n {
        let mut best_u = usize::MAX;
        for u in 0..n {
            if proc_assigned[u] {
                continue;
            }
            if best_u == usize::MAX
                || comm_to_assigned[u] > comm_to_assigned[best_u]
                || (comm_to_assigned[u] == comm_to_assigned[best_u] && volume[u] > volume[best_u])
            {
                best_u = u;
            }
        }
        let u = best_u;
        placed.clear();
        for (x, c) in comm.edges(u as NodeId) {
            if proc_assigned[x as usize] {
                placed.push((sigma[x as usize], c));
            }
        }
        // pick PE minimizing the objective increase (ties: lowest id)
        let mut best_p = usize::MAX;
        let mut best_cost = u64::MAX;
        for q in 0..n {
            if pe_used[q] {
                continue;
            }
            let cost: u64 = placed.iter().map(|&(px, c)| c * oracle.distance(q as u32, px)).sum();
            if cost < best_cost {
                best_cost = cost;
                best_p = q;
            }
        }
        sigma[u] = best_p as u32;
        proc_assigned[u] = true;
        pe_used[best_p] = true;
        for (x, w) in comm.edges(u as NodeId) {
            comm_to_assigned[x as usize] += w;
        }
    }
    Mapping { sigma }
}

/// Top-Down multilevel construction (§3.1): recursively split the
/// communication graph into `a_k` perfectly balanced blocks, assign each
/// block a contiguous PE range, recurse with the next hierarchy level.
pub fn top_down(
    comm: &Graph,
    hierarchy: &Hierarchy,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Mapping {
    let n = comm.n();
    assert_eq!(n, hierarchy.n_pes(), "processes ({n}) != PEs ({})", hierarchy.n_pes());
    let mut sigma = vec![u32::MAX; n];
    let nodes: Vec<NodeId> = (0..n as NodeId).collect();
    top_down_rec(comm, &nodes, hierarchy, hierarchy.levels(), 0, &mut sigma, cfg, rng);
    Mapping { sigma }
}

#[allow(clippy::too_many_arguments)]
fn top_down_rec(
    orig: &Graph,
    nodes: &[NodeId],
    h: &Hierarchy,
    level: usize,
    pe_offset: u32,
    sigma: &mut [u32],
    cfg: &PartitionConfig,
    rng: &mut Rng,
) {
    if level <= 1 {
        // innermost subsystem: all PEs equidistant — any order is optimal
        for (i, &v) in nodes.iter().enumerate() {
            sigma[v as usize] = pe_offset + i as u32;
        }
        return;
    }
    let blocks = h.s[level - 1] as usize;
    let sub_size = h.subsystem_size(level - 1) as usize;
    debug_assert_eq!(nodes.len(), blocks * sub_size);
    if blocks == 1 {
        top_down_rec(orig, nodes, h, level - 1, pe_offset, sigma, cfg, rng);
        return;
    }
    let (sub, map) = induced_subgraph(orig, nodes);
    let part = partition_kway(&sub, blocks, cfg, rng);
    let mut members: Vec<Vec<NodeId>> = vec![Vec::with_capacity(sub_size); blocks];
    for v in 0..sub.n() {
        members[part.block[v] as usize].push(map[v]);
    }
    for (b, member) in members.into_iter().enumerate() {
        debug_assert_eq!(member.len(), sub_size, "block {b} not perfectly balanced");
        top_down_rec(
            orig,
            &member,
            h,
            level - 1,
            pe_offset + (b * sub_size) as u32,
            sigma,
            cfg,
            rng,
        );
    }
}

/// Top-Down construction over a non-uniform [`SubsystemTree`]: at each
/// inner subsystem, partition the induced communication subgraph into
/// blocks of *exactly* the child subtrees' PE counts (unequal in general —
/// [`partition_exact_sizes`]); each block recurses into its child, whose
/// contiguous PE range the tree prescribes. The uniform case degenerates to
/// [`top_down`]'s split shape level by level.
pub fn top_down_tree(
    comm: &Graph,
    tree: &SubsystemTree,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Mapping {
    let n = comm.n();
    assert_eq!(n, tree.n_pes(), "processes ({n}) != PEs ({})", tree.n_pes());
    let mut sigma = vec![u32::MAX; n];
    let nodes: Vec<NodeId> = (0..n as NodeId).collect();
    top_down_tree_rec(comm, &nodes, tree, 0, &mut sigma, cfg, rng);
    Mapping { sigma }
}

fn top_down_tree_rec(
    orig: &Graph,
    verts: &[NodeId],
    tree: &SubsystemTree,
    node: u32,
    sigma: &mut [u32],
    cfg: &PartitionConfig,
    rng: &mut Rng,
) {
    let s = tree.nodes()[node as usize];
    debug_assert_eq!(verts.len(), s.pe_count as usize);
    if s.n_children == 0 {
        // leaf subsystem: all PEs equidistant — any order is optimal
        for (i, &v) in verts.iter().enumerate() {
            sigma[v as usize] = s.pe_start + i as u32;
        }
        return;
    }
    if s.n_children == 1 {
        top_down_tree_rec(orig, verts, tree, s.first_child, sigma, cfg, rng);
        return;
    }
    let children: Vec<u32> = tree.children(node).collect();
    let sizes: Vec<Weight> =
        children.iter().map(|&c| tree.nodes()[c as usize].pe_count as Weight).collect();
    let (sub, map) = induced_subgraph(orig, verts);
    let part = partition_exact_sizes(&sub, &sizes, cfg, rng);
    let mut members: Vec<Vec<NodeId>> =
        sizes.iter().map(|&bs| Vec::with_capacity(bs as usize)).collect();
    for v in 0..sub.n() {
        members[part.block[v] as usize].push(map[v]);
    }
    for (b, member) in members.into_iter().enumerate() {
        debug_assert_eq!(member.len() as Weight, sizes[b], "block {b} missed its size");
        top_down_tree_rec(orig, &member, tree, children[b], sigma, cfg, rng);
    }
}

/// Bottom-Up multilevel construction (§3.1): partition the communication
/// graph into blocks of exactly `a_1` vertices, contract (summing parallel
/// edges), repeat with `a_2`, …; unwinding the recursion assigns block
/// positions and finally PE ranks.
pub fn bottom_up(
    comm: &Graph,
    hierarchy: &Hierarchy,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Mapping {
    let n = comm.n();
    assert_eq!(n, hierarchy.n_pes());
    let sigma = bottom_up_rec(comm, &hierarchy.s, cfg, rng);
    Mapping { sigma }
}

/// Returns the position (PE index within `0..g.n()` capacity units) of each
/// vertex of `g`.
fn bottom_up_rec(g: &Graph, levels: &[u64], cfg: &PartitionConfig, rng: &mut Rng) -> Vec<u32> {
    if levels.is_empty() || g.n() <= 1 {
        return (0..g.n() as u32).collect();
    }
    let a = levels[0] as usize;
    debug_assert_eq!(g.n() % a, 0, "hierarchy does not divide graph size");
    let blocks = g.n() / a;
    let part = partition_kway(g, blocks, cfg, rng);
    let coarse = contract(g, &part.block, blocks);
    let pos_of_block = bottom_up_rec(&coarse, &levels[1..], cfg, rng);
    // rank of each vertex within its block (order of appearance)
    let mut counter = vec![0u32; blocks];
    let mut pos = vec![0u32; g.n()];
    for v in 0..g.n() {
        let b = part.block[v] as usize;
        pos[v] = pos_of_block[b] * a as u32 + counter[b];
        counter[b] += 1;
    }
    pos
}

/// Bottom-Up construction over a non-uniform [`SubsystemTree`]: partition
/// into blocks of exactly the leaf sizes, contract, recurse on the
/// leaf-folded machine ([`SubsystemTree::fold_leaves`], exact by
/// ultrametricity), then unwind placing blocks by sequential allocation in
/// coarse-position order — the unequal-blocks analogue of [`bottom_up`]'s
/// `pos·a + rank` rule. When a block's size differs from the leaf at its
/// assigned position the layout shears across leaf boundaries; downstream
/// refinement absorbs that (the machine fold itself stays exact).
pub fn bottom_up_tree(
    comm: &Graph,
    tree: &SubsystemTree,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Mapping {
    let n = comm.n();
    assert_eq!(n, tree.n_pes(), "processes ({n}) != PEs ({})", tree.n_pes());
    Mapping { sigma: bottom_up_tree_rec(comm, tree, cfg, rng) }
}

/// Returns the position (PE index) of each vertex of `g` under `tree`.
fn bottom_up_tree_rec(
    g: &Graph,
    tree: &SubsystemTree,
    cfg: &PartitionConfig,
    rng: &mut Rng,
) -> Vec<u32> {
    let sizes = tree.leaf_sizes();
    if g.n() <= 1 || sizes.len() < 2 {
        // flat subsystem (single leaf): all PEs equidistant
        return (0..g.n() as u32).collect();
    }
    debug_assert_eq!(sizes.iter().sum::<u64>(), g.n() as u64);
    let k = sizes.len();
    let wsizes: Vec<Weight> = sizes.iter().map(|&bs| bs as Weight).collect();
    let part = partition_exact_sizes(g, &wsizes, cfg, rng);
    let coarse = contract(g, &part.block, k);
    let folded = tree.fold_leaves().expect("a multi-leaf non-unit tree folds its leaves");
    let pos_of_block = bottom_up_tree_rec(&coarse, &folded, cfg, rng);
    // sequential allocation: lay the blocks out in coarse-position order,
    // each taking a consecutive fine range of its own size
    let mut block_at_pos = vec![0u32; k];
    for (b, &p) in pos_of_block.iter().enumerate() {
        block_at_pos[p as usize] = b as u32;
    }
    let mut start = vec![0u32; k];
    let mut acc = 0u32;
    for &b in &block_at_pos {
        start[b as usize] = acc;
        acc += sizes[b as usize] as u32;
    }
    debug_assert_eq!(acc as usize, g.n(), "block sizes must tile the PEs");
    let mut counter = vec![0u32; k];
    let mut pos = vec![0u32; g.n()];
    for v in 0..g.n() {
        let b = part.block[v] as usize;
        pos[v] = start[b] + counter[b];
        counter[b] += 1;
    }
    pos
}

/// Dual recursive bisection (LibTopoMap-style [15]): split the process set
/// in half (exactly) and the contiguous PE range at the same point; recurse.
/// Intentionally hierarchy-*unaware*, reproducing the paper's observation
/// that its quality degrades off powers of two.
pub fn rcb(comm: &Graph, cfg: &PartitionConfig, rng: &mut Rng) -> Mapping {
    let n = comm.n();
    let mut sigma = vec![u32::MAX; n];
    let nodes: Vec<NodeId> = (0..n as NodeId).collect();
    rcb_rec(comm, &nodes, 0, &mut sigma, cfg, rng);
    Mapping { sigma }
}

fn rcb_rec(
    orig: &Graph,
    nodes: &[NodeId],
    pe_offset: u32,
    sigma: &mut [u32],
    cfg: &PartitionConfig,
    rng: &mut Rng,
) {
    match nodes.len() {
        0 => return,
        1 => {
            sigma[nodes[0] as usize] = pe_offset;
            return;
        }
        _ => {}
    }
    let (sub, map) = induced_subgraph(orig, nodes);
    let sizes = exact_block_sizes(nodes.len(), 2);
    let bis = bisect_multilevel(&sub, sizes[0], cfg, rng);
    let left: Vec<NodeId> = (0..sub.n()).filter(|&v| bis[v] == 0).map(|v| map[v]).collect();
    let right: Vec<NodeId> = (0..sub.n()).filter(|&v| bis[v] == 1).map(|v| map[v]).collect();
    rcb_rec(orig, &left, pe_offset, sigma, cfg, rng);
    rcb_rec(orig, &right, pe_offset + left.len() as u32, sigma, cfg, rng);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random_geometric_graph;
    use crate::mapping::objective::objective;

    fn setup(nexp: usize, seed: u64) -> (Graph, Hierarchy, Machine) {
        let mut rng = Rng::new(seed);
        let g = random_geometric_graph(1 << nexp, &mut rng);
        let h = Hierarchy::new(vec![4, 16, (1u64 << nexp) / 64], vec![1, 10, 100]).unwrap();
        let o = Machine::implicit(h.clone());
        (g, h, o)
    }

    #[test]
    fn all_constructions_are_bijections() {
        let (g, h, o) = setup(8, 1);
        let mut rng = Rng::new(2);
        let cfg = PartitionConfig::perfectly_balanced();
        for (name, m) in [
            ("identity", identity(g.n())),
            ("random", random(g.n(), &mut rng)),
            ("mm", mueller_merbach(&g, &o)),
            ("gac", greedy_all_c(&g, &h)),
            ("topdown", top_down(&g, &h, &cfg, &mut rng)),
            ("bottomup", bottom_up(&g, &h, &cfg, &mut rng)),
            ("rcb", rcb(&g, &cfg, &mut rng)),
        ] {
            m.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn topdown_beats_random_clearly() {
        let (g, h, o) = setup(9, 3);
        let mut rng = Rng::new(4);
        let cfg = PartitionConfig::perfectly_balanced();
        let j_random = objective(&g, &o, &random(g.n(), &mut rng));
        let j_td = objective(&g, &o, &top_down(&g, &h, &cfg, &mut rng));
        assert!(
            (j_td as f64) < 0.7 * j_random as f64,
            "topdown {j_td} vs random {j_random}"
        );
    }

    #[test]
    fn topdown_beats_mueller_merbach_on_average() {
        // Figure 3's headline: Top-Down ≈ 52% better than Müller-Merbach.
        // One instance at moderate size: require strictly better.
        let (g, h, o) = setup(9, 5);
        let mut rng = Rng::new(6);
        let cfg = PartitionConfig::perfectly_balanced();
        let j_mm = objective(&g, &o, &mueller_merbach(&g, &o));
        let j_td = objective(&g, &o, &top_down(&g, &h, &cfg, &mut rng));
        assert!(j_td < j_mm, "topdown {j_td} vs MM {j_mm}");
    }

    #[test]
    fn bottom_up_quality_reasonable() {
        let (g, h, o) = setup(8, 7);
        let mut rng = Rng::new(8);
        let cfg = PartitionConfig::perfectly_balanced();
        let j_bu = objective(&g, &o, &bottom_up(&g, &h, &cfg, &mut rng));
        let j_rand = objective(&g, &o, &random(g.n(), &mut rng));
        assert!((j_bu as f64) < 0.7 * j_rand as f64, "bottomup {j_bu} vs random {j_rand}");
    }

    #[test]
    fn greedy_all_c_not_worse_than_mm_much() {
        // GreedyAllC links process and PE choice; on average it slightly
        // improves on MM (paper: ~1%). Allow slack on a single instance.
        let (g, h, o) = setup(8, 9);
        let j_mm = objective(&g, &o, &mueller_merbach(&g, &o));
        let j_gac = objective(&g, &o, &greedy_all_c(&g, &h));
        assert!((j_gac as f64) < 1.5 * j_mm as f64, "gac {j_gac} vs mm {j_mm}");
    }

    #[test]
    fn gac_coincides_with_mm_on_ultrametric_distances() {
        // see the doc comment on `greedy_all_c`: this equality is a theorem
        // for homogeneous hierarchies + lowest-id ties, and a regression
        // guard for both implementations.
        let (g, h, o) = setup(8, 21);
        let m1 = mueller_merbach(&g, &o);
        let m2 = greedy_all_c(&g, &h);
        assert_eq!(m1.sigma, m2.sigma);
    }

    #[test]
    fn mm_matches_with_explicit_oracle() {
        // implicit vs explicit oracle must give identical constructions
        let (g, h, o_imp) = setup(7, 10);
        let o_exp = Machine::explicit(&h);
        let m1 = mueller_merbach(&g, &o_imp);
        let m2 = mueller_merbach(&g, &o_exp);
        assert_eq!(m1.sigma, m2.sigma);
    }

    #[test]
    fn rcb_handles_non_power_of_two() {
        let mut rng = Rng::new(11);
        let g = random_geometric_graph(96, &mut rng); // 96 = 3 * 32
        let cfg = PartitionConfig::perfectly_balanced();
        let m = rcb(&g, &cfg, &mut rng);
        m.validate().unwrap();
    }

    #[test]
    fn topdown_respects_hierarchy_locality() {
        // in a Top-Down mapping, heavy subgraphs land in the same subsystem:
        // count intra-leaf edges vs a random mapping.
        let (g, h, _o) = setup(8, 12);
        let mut rng = Rng::new(13);
        let cfg = PartitionConfig::perfectly_balanced();
        let td = top_down(&g, &h, &cfg, &mut rng);
        let rd = random(g.n(), &mut rng);
        let intra = |m: &Mapping| {
            let mut c = 0u64;
            for u in 0..g.n() as NodeId {
                for (v, w) in g.edges(u) {
                    if v > u && h.same_leaf_group(m.sigma[u as usize], m.sigma[v as usize]) {
                        c += w;
                    }
                }
            }
            c
        };
        assert!(intra(&td) > 2 * intra(&rd), "td {} vs random {}", intra(&td), intra(&rd));
    }

    #[test]
    fn generic_gac_coincides_with_bucketed_on_hierarchies() {
        // the bucketed cost Σ_i d_i (A_i - A_{i-1}) IS Σ_x c·D(q, σx); with
        // identical lowest-id tie-breaks the two implementations must agree
        // move for move on any hierarchy
        let (g, h, o) = setup(7, 33);
        let bucketed = greedy_all_c(&g, &h);
        let generic = greedy_all_c_generic(&g, &o);
        assert_eq!(bucketed.sigma, generic.sigma);
    }

    #[test]
    fn constructions_run_on_grid_and_torus_machines() {
        let mut rng = Rng::new(34);
        let g = random_geometric_graph(96, &mut rng);
        let cfg = PartitionConfig::perfectly_balanced();
        for spec in ["grid:12x8@1", "torus:4x4x6@1", "fattree:4,8:8", "dragonfly:3,3,2:12"] {
            let machine = Machine::parse(spec).unwrap();
            for c in [
                Construction::Identity,
                Construction::Random,
                Construction::MuellerMerbach,
                Construction::GreedyAllC,
                Construction::TopDown,
                Construction::BottomUp,
                Construction::Rcb,
            ] {
                let m = initial(&g, &machine, &machine, c, &cfg, &mut rng);
                m.validate().unwrap_or_else(|e| panic!("{spec}/{c:?}: {e}"));
            }
        }
    }

    #[test]
    fn grid_topdown_multisection_respects_rows() {
        // on a grid machine, top_down multisects along dimensions: the
        // placement must beat random, like the hierarchical case
        let mut rng = Rng::new(35);
        let g = random_geometric_graph(256, &mut rng);
        let machine = Machine::parse("grid:16x16@1").unwrap();
        let cfg = PartitionConfig::perfectly_balanced();
        let td = initial(&g, &machine, &machine, Construction::TopDown, &cfg, &mut rng);
        let rd = random(g.n(), &mut rng);
        let j_td = objective(&g, &machine, &td);
        let j_rd = objective(&g, &machine, &rd);
        assert!((j_td as f64) < 0.8 * j_rd as f64, "topdown {j_td} vs random {j_rd}");
    }

    #[test]
    fn fattree_topdown_beats_random_and_respects_pods() {
        // unequal pods (32 and 64 PEs): the tree multisection must place
        // heavy subgraphs inside pods, clearly beating random placement
        let mut rng = Rng::new(36);
        let g = random_geometric_graph(96, &mut rng);
        let machine = Machine::parse("fattree:2,4:16@1:10:100").unwrap();
        let cfg = PartitionConfig::perfectly_balanced();
        let td = initial(&g, &machine, &machine, Construction::TopDown, &cfg, &mut rng);
        td.validate().unwrap();
        let rd = random(g.n(), &mut rng);
        let j_td = objective(&g, &machine, &td);
        let j_rd = objective(&g, &machine, &rd);
        assert!((j_td as f64) < 0.8 * j_rd as f64, "topdown {j_td} vs random {j_rd}");
        // intra-leaf traffic dominates random's, like the hierarchy case
        let t = machine.tree().unwrap();
        let intra = |m: &Mapping| {
            let mut c = 0u64;
            for u in 0..g.n() as NodeId {
                for (v, w) in g.edges(u) {
                    if v > u && t.same_leaf_group(m.sigma[u as usize], m.sigma[v as usize]) {
                        c += w;
                    }
                }
            }
            c
        };
        assert!(intra(&td) > 2 * intra(&rd), "td {} vs random {}", intra(&td), intra(&rd));
    }

    #[test]
    fn fattree_bottom_up_quality_reasonable() {
        let mut rng = Rng::new(37);
        let g = random_geometric_graph(96, &mut rng);
        let machine = Machine::parse("fattree:2,4:16@1:10:100").unwrap();
        let cfg = PartitionConfig::perfectly_balanced();
        let bu = initial(&g, &machine, &machine, Construction::BottomUp, &cfg, &mut rng);
        bu.validate().unwrap();
        let j_bu = objective(&g, &machine, &bu);
        let j_rd = objective(&g, &machine, &random(g.n(), &mut rng));
        assert!((j_bu as f64) < 0.8 * j_rd as f64, "bottomup {j_bu} vs random {j_rd}");
    }

    #[test]
    fn empty_and_single() {
        let g0 = crate::graph::from_edges(0, &[]);
        let h1 = Hierarchy::new(vec![1], vec![1]).unwrap();
        let o = Machine::implicit(h1.clone());
        // n=0 valid for identity/random only; constructions need n == PEs
        assert_eq!(identity(0).n(), 0);
        let g1 = crate::graph::from_edges(1, &[]);
        let m = mueller_merbach(&g1, &o);
        assert_eq!(m.sigma, vec![0]);
        let mut rng = Rng::new(1);
        let cfg = PartitionConfig::default();
        let m = top_down(&g1, &h1, &cfg, &mut rng);
        assert_eq!(m.sigma, vec![0]);
        let m = bottom_up(&g1, &h1, &cfg, &mut rng);
        assert_eq!(m.sigma, vec![0]);
        drop(g0);
    }
}
