//! `qapmap` — CLI for the process-mapping library and service.
//!
//! Subcommands:
//!
//! * `map`        — run one mapping job from a METIS file or a generator.
//! * `serve`      — start the rank-reordering TCP service.
//! * `client`     — submit a job to a running service.
//! * `stats`      — query a running service's metrics (`STATS` verb).
//! * `gen`        — generate a benchmark instance to a METIS file.
//! * `partition`  — partition a graph (the §4.1 instance pipeline).
//! * `verify`     — cross-check the sparse objective against the XLA path.
//!
//! Examples:
//!
//! ```text
//! qapmap map --inst rgg12 --blocks 256 --S 4:16:4 --D 1:10:100 --algo topdown+Nc10
//! qapmap serve --addr 127.0.0.1:7447 --workers 2
//! qapmap client --addr 127.0.0.1:7447 --inst rgg10 --blocks 128 --S 4:16:2 --D 1:10:100
//! ```

use anyhow::{anyhow, bail, Context, Result};
use qapmap::api::{MachineResolution, MapJobBuilder, MapSession, OracleMode, VerifyPolicy};
use qapmap::coordinator::{wire, Coordinator, RemapRequest};
use qapmap::graph::{io as gio, EdgeDelta, Graph, NodeId, Weight};
use qapmap::mapping::algorithms::AlgorithmSpec;
use qapmap::model::build_instance;
use qapmap::model::topology::Machine;
use qapmap::partition::{partition_kway, PartitionConfig};
use qapmap::runtime::{QapRuntime, RuntimeHandle};
use qapmap::util::{Args, Rng};
use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        usage();
        std::process::exit(2);
    }
    let mut cmd = raw.remove(0);
    // `client remap` is a two-word subcommand: peel the second word before
    // option parsing
    if cmd == "client" && raw.first().is_some_and(|a| a == "remap") {
        raw.remove(0);
        cmd = "client-remap".to_string();
    }
    let args = Args::parse_from(raw);
    let result = match cmd.as_str() {
        "map" => cmd_map(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "client-remap" => cmd_client_remap(&args),
        "stats" => cmd_stats(&args),
        "gen" => cmd_gen(&args),
        "partition" => cmd_partition(&args),
        "verify" => cmd_verify(&args),
        "infer" => cmd_infer(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?} — try `qapmap help`")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "qapmap — process mapping & sparse quadratic assignment\n\
         commands:\n  \
         map        --inst <name>|--graph <file.metis> --blocks <k>\n  \
                    [--machine hier:4:16:2@1:10:100 | grid:8x8@1 | torus:4x4x4@1\n  \
                     | fattree:4,8:8@1:10:100 | dragonfly:4,4,4:8@1:10:100]\n  \
                    [--S a:b:c --D x:y:z]   (legacy hierarchy notation)\n  \
                    [--algo topdown+Nc10 | topdown+gc:nc10 | topdown+gc:nccyc10 | ml:topdown+Nc5]\n  \
                    [--seed 1] [--reps 1] [--threads 1]   (0 = auto-detect)\n  \
                    [--verify] [--explicit-distances] [--levels 16] [--coarsen-limit 64]\n  \
                    [--deadline-ms N]   (anytime: best valid mapping at the deadline)\n  \
         serve      [--addr 127.0.0.1:7447] [--workers N] [--queue 64] [--no-xla]\n  \
                    [--session-cache 16] [--max-conns 64] [--inflight 8] [--threads 1]\n  \
                    [--idle-timeout-ms 60000] [--grace-ms 3000]\n  \
         client     --addr host:port (same instance options as map, plus\n  \
                    [--deadline-ms N] [--retries 1] for retryable refusals)\n  \
         client remap  --addr host:port (instance options as client): MAP the\n  \
                    instance, then REMAP it on the same connection with a\n  \
                    drifted edge set — [--deltas file] (lines: u v w) or\n  \
                    [--drift K] random weight perturbations (default 8)\n  \
         stats      [--addr 127.0.0.1:7447] — query a running service's metrics\n  \
         gen        --inst rgg12 --out file.metis [--seed 1]\n  \
         partition  --graph file.metis --blocks k [--out part.txt] [--epsilon 0.0]\n  \
         verify     --inst rgg8 --blocks 64 --S 4:16 --D 1:10 [--algo topdown]\n  \
         infer      --matrix dist.txt   (whitespace-separated n*n matrix) —\n  \
                    recognize a hierarchy (S/D), grid or torus"
    );
}

/// Load or build the communication graph named by --graph / --inst+--blocks.
fn load_comm(args: &Args, rng: &mut Rng) -> Result<Graph> {
    if let Some(path) = args.options.get("graph") {
        let g = gio::read_metis_file(Path::new(path)).map_err(|e| anyhow!(e))?;
        return Ok(g);
    }
    let inst = args.get("inst", "rgg12");
    let blocks: usize = args.get_as("blocks", 256);
    let app = qapmap::gen::by_name(inst, rng).map_err(|e| anyhow!(e))?;
    if app.n() < blocks {
        bail!("instance {inst} has {} vertices < {blocks} blocks", app.n());
    }
    Ok(build_instance(&app, blocks, rng))
}

/// Resolve `--machine` (full grammar) or the legacy `--S`/`--D` notation
/// into a machine for an `n`-process instance; the shared logic (including
/// the fold-don't-flatten default when nothing is given) lives in
/// [`qapmap::api::resolve_machine`].
fn machine_for(args: &Args, n: usize) -> Result<(Machine, MachineResolution)> {
    qapmap::api::resolve_machine(n, args.get("machine", ""), args.get("S", ""), args.get("D", ""))
        .map_err(|e| anyhow!(e))
}

/// One line describing how the machine was chosen (printed by `map`).
fn describe_machine(r: &MachineResolution) -> String {
    let mut line = format!("machine: {}", r.spec);
    if r.inferred {
        line.push_str(" (inferred from n");
        if r.partial_top_folded {
            line.push_str("; default template partially folded");
        }
        line.push(')');
    }
    line
}

fn cmd_map(args: &Args) -> Result<()> {
    let seed: u64 = args.get_as("seed", 1);
    let mut rng = Rng::new(seed);
    let comm = load_comm(args, &mut rng)?;
    let (machine, resolution) = machine_for(args, comm.n())?;
    println!("{}", describe_machine(&resolution));
    let spec = AlgorithmSpec::parse(args.get("algo", "topdown+Nc10")).map_err(|e| anyhow!(e))?;
    let verify = args.flag("verify");
    let mut builder = MapJobBuilder::for_machine(comm, machine)
        .machine_resolution(resolution)
        .algorithm(spec)
        .oracle_mode(if args.flag("explicit-distances") {
            OracleMode::Explicit
        } else {
            OracleMode::Implicit
        })
        .repetitions(args.get_as("reps", 1))
        .seed(seed)
        .threads(args.get_as("threads", 1))
        .partition_config(PartitionConfig::perfectly_balanced())
        .levels(args.get_as("levels", 16))
        .coarsen_limit(args.get_as("coarsen-limit", 64))
        .verify(if verify { VerifyPolicy::IfAvailable } else { VerifyPolicy::Skip });
    if let Some(ms) = args.options.get("deadline-ms") {
        builder = builder.deadline_ms(ms.parse().context("--deadline-ms")?);
    }
    let job = builder.build().map_err(|e| anyhow!(e))?;
    let runtime = if verify {
        Some(RuntimeHandle::spawn_default().context("loading artifacts")?)
    } else {
        None
    };
    let mut session = MapSession::with_runtime(job, runtime);
    let report = session.run();
    let job = session.job();
    println!(
        "instance: n={} m={} (m/n={:.1})  algorithm: {}",
        job.comm().n(),
        job.comm().m(),
        job.comm().density(),
        report.algorithm
    );
    println!(
        "objective: {} (initial {}, improvement {:.1}%)",
        report.objective,
        report.objective_initial,
        report.improvement_pct()
    );
    if report.timed_out {
        println!("deadline hit: anytime stop — the mapping is the best found so far, not converged");
    }
    println!(
        "time: construct {:.3}s + local search {:.3}s = {:.3}s (swaps: {} applied / {} evaluated)",
        report.construct_secs,
        report.ls_secs,
        report.total_secs,
        report.best().improved,
        report.best().evaluated
    );
    if report.reps.len() > 1 {
        for (i, rep) in report.reps.iter().enumerate() {
            println!(
                "  rep {i}: seed={} J={} (initial {}) in {:.3}s{}",
                rep.seed,
                rep.objective,
                rep.objective_initial,
                rep.construct_secs + rep.ls_secs,
                if i == report.best_rep { "  <- best" } else { "" }
            );
        }
    } else if report.short_circuited {
        println!("(deterministic algorithm: repetitions short-circuited to 1)");
    }
    let levels = &report.best().levels;
    if !levels.is_empty() {
        println!("V-cycle ({} levels, coarsest first):", levels.len());
        for (i, l) in levels.iter().enumerate() {
            println!(
                "  level {i}: n={:<6} J {} -> {} ({} evaluated / {} improved / {} rounds)",
                l.n, l.objective_initial, l.objective, l.evaluated, l.improved, l.rounds
            );
        }
    }
    if verify {
        match (report.xla_objective, report.verified) {
            (Some(xj), Some(ok)) => println!(
                "xla verification: {xj} vs exact {} -> {}",
                report.objective,
                if ok { "OK" } else { "MISMATCH" }
            ),
            _ => match &report.verify_error {
                Some(e) => bail!("xla verification failed to run: {e}"),
                None => println!("xla verification: instance larger than all artifacts (skipped)"),
            },
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7447");
    let workers: usize = args.get_as("workers", 2);
    let queue: usize = args.get_as("queue", 64);
    let session_cache: usize = args.get_as("session-cache", 16);
    let cfg = wire::ServeConfig {
        max_connections: args.get_as("max-conns", 64),
        inflight_per_connection: args.get_as("inflight", 8),
        idle_timeout_ms: args.get_as("idle-timeout-ms", 60_000),
        shutdown_grace_ms: args.get_as("grace-ms", 3_000),
    };
    let runtime = if args.flag("no-xla") {
        None
    } else {
        match RuntimeHandle::spawn_default() {
            Ok(rt) => {
                println!("loaded XLA artifacts from {}", QapRuntime::artifact_dir().display());
                Some(rt)
            }
            Err(e) => {
                eprintln!("warning: XLA runtime unavailable ({e:#}); serving without verification");
                None
            }
        }
    };
    let threads: usize = args.get_as("threads", 1);
    let coordinator =
        Arc::new(Coordinator::start_full(workers, queue, runtime, session_cache, threads));
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "qapmap service listening on {addr} with {workers} workers \
         (queue {queue}, {session_cache} warm sessions, ≤{} conns, \
         {threads} threads/job default)",
        cfg.max_connections
    );
    let stop = Arc::new(AtomicBool::new(false));
    wire::serve_with(listener, coordinator, stop, cfg)
}

/// Query a running service's metrics over the v2 `STATS` verb.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7447");
    let mut client = wire::Client::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let snapshot = client.stats()?;
    println!("{snapshot}");
    client.quit()?;
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7447");
    let seed: u64 = args.get_as("seed", 1);
    let mut rng = Rng::new(seed);
    let comm = load_comm(args, &mut rng)?;
    let (machine, resolution) = machine_for(args, comm.n())?;
    let mut builder = MapJobBuilder::for_machine(comm, machine)
        .machine_resolution(resolution)
        .algorithm_name(args.get("algo", "topdown+Nc10"))
        .map_err(|e| anyhow!(e))?
        .repetitions(args.get_as("reps", 1))
        .seed(seed)
        .threads(args.get_as("threads", 1))
        .levels(args.get_as("levels", 16))
        .coarsen_limit(args.get_as("coarsen-limit", 64))
        .verify(if args.flag("verify") { VerifyPolicy::IfAvailable } else { VerifyPolicy::Skip });
    if let Some(ms) = args.options.get("deadline-ms") {
        builder = builder.deadline_ms(ms.parse().context("--deadline-ms")?);
    }
    let job = builder.build().map_err(|e| anyhow!(e))?;
    // BUSY/EXPIRED/unavailable are retryable refusals: back off and resubmit
    let policy = wire::RetryPolicy {
        max_attempts: args.get_as("retries", 1u32).max(1),
        ..Default::default()
    };
    let resp = wire::request_with_retry(addr, &job.to_request(seed), &policy)?;
    match &resp.error {
        Some(e) => bail!("service error: {e}"),
        None => {
            println!(
                "id={} objective={} initial={} construct={:.3}s ls={:.3}s verified={:?} reps={}{}",
                resp.id,
                resp.objective,
                resp.objective_initial,
                resp.construct_secs,
                resp.ls_secs,
                resp.verified,
                resp.reps.len(),
                if resp.timed_out { " (timed out: best-so-far mapping)" } else { "" }
            );
            Ok(())
        }
    }
}

/// `client remap`: map an instance over a persistent connection, then send
/// an edge-delta batch as a `REMAP` on the same connection — the service
/// resumes the warm session instead of rebuilding (gain-cache re-seed for
/// weight drifts, cold rerun for structural batches).
fn cmd_client_remap(args: &Args) -> Result<()> {
    let addr = args.get("addr", "127.0.0.1:7447");
    let seed: u64 = args.get_as("seed", 1);
    let mut rng = Rng::new(seed);
    let comm = load_comm(args, &mut rng)?;
    let (machine, resolution) = machine_for(args, comm.n())?;
    let mut builder = MapJobBuilder::for_machine(comm, machine)
        .machine_resolution(resolution)
        .algorithm_name(args.get("algo", "topdown+gc:nc10"))
        .map_err(|e| anyhow!(e))?
        .seed(seed)
        .threads(args.get_as("threads", 1))
        .levels(args.get_as("levels", 16))
        .coarsen_limit(args.get_as("coarsen-limit", 64));
    if let Some(ms) = args.options.get("deadline-ms") {
        builder = builder.deadline_ms(ms.parse().context("--deadline-ms")?);
    }
    let job = builder.build().map_err(|e| anyhow!(e))?;
    let req = job.to_request(seed);
    let deltas = load_deltas(args, &req.comm, &mut rng)?;
    let mut client = wire::Client::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let base = client.map(&req)?;
    if let Some(e) = &base.error {
        bail!("service error on MAP: {e}");
    }
    println!(
        "mapped: id={} objective={} in {:.3}s",
        base.id,
        base.objective,
        base.construct_secs + base.ls_secs
    );
    let remap =
        RemapRequest { id: req.id, deltas, threads: None, deadline_ms: req.deadline_ms };
    let k = remap.deltas.len();
    let resp = client.remap(&remap)?;
    match &resp.error {
        Some(e) => bail!("service error on REMAP: {e}"),
        None => println!(
            "remapped {k} deltas: objective {} -> {} (ls {:.3}s, {} evaluated)",
            resp.objective_initial, resp.objective, resp.ls_secs, resp.stats.evaluated
        ),
    }
    client.quit()?;
    Ok(())
}

/// Delta source for `client remap`: an explicit `--deltas` file (one
/// `u v w` triple per line, `#` comments), or `--drift K` deterministic
/// random weight bumps on existing edges (default 8).
fn load_deltas(args: &Args, comm: &Graph, rng: &mut Rng) -> Result<Vec<EdgeDelta>> {
    if let Some(path) = args.options.get("deltas") {
        let text = std::fs::read_to_string(path)?;
        let mut deltas = Vec::new();
        for line in text.lines() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = t.split_whitespace().collect();
            if toks.len() != 3 {
                bail!("bad delta line {t:?} (want: u v w)");
            }
            deltas.push(EdgeDelta {
                u: toks[0].parse()?,
                v: toks[1].parse()?,
                w: toks[2].parse()?,
            });
        }
        return Ok(deltas);
    }
    let k: usize = args.get_as("drift", 8);
    let mut edges: Vec<(NodeId, NodeId, Weight)> = Vec::new();
    for u in 0..comm.n() as NodeId {
        for (v, w) in comm.edges(u) {
            if v > u {
                edges.push((u, v, w));
            }
        }
    }
    if edges.is_empty() {
        bail!("instance has no edges to drift");
    }
    let mut deltas = Vec::with_capacity(k);
    for _ in 0..k {
        let (u, v, w) = edges[rng.next_bounded(edges.len() as u64) as usize];
        deltas.push(EdgeDelta { u, v, w: w + 1 + rng.next_bounded(4) });
    }
    Ok(deltas)
}

fn cmd_gen(args: &Args) -> Result<()> {
    let seed: u64 = args.get_as("seed", 1);
    let mut rng = Rng::new(seed);
    let inst = args.get("inst", "rgg12");
    let out = args.get("out", "instance.metis");
    let g = qapmap::gen::by_name(inst, &mut rng).map_err(|e| anyhow!(e))?;
    gio::write_metis_file(&g, Path::new(out))?;
    println!("wrote {inst} (n={} m={}) to {out}", g.n(), g.m());
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let seed: u64 = args.get_as("seed", 1);
    let mut rng = Rng::new(seed);
    let path = args.options.get("graph").ok_or_else(|| anyhow!("--graph required"))?;
    let g = gio::read_metis_file(Path::new(path)).map_err(|e| anyhow!(e))?;
    let k: usize = args.get_as("blocks", 2);
    let epsilon: f64 = args.get_as("epsilon", 0.0);
    let cfg = PartitionConfig { epsilon, ..PartitionConfig::default() };
    let (p, secs) = qapmap::util::timer::time(|| partition_kway(&g, k, &cfg, &mut rng));
    println!(
        "partitioned n={} into k={k}: cut={} balanced={} in {:.3}s",
        g.n(),
        p.cut(&g),
        p.is_balanced(&g, epsilon, true),
        secs
    );
    if let Some(out) = args.options.get("out") {
        let body: String = p.block.iter().map(|b| format!("{b}\n")).collect();
        std::fs::write(out, body)?;
        println!("wrote block vector to {out}");
    }
    Ok(())
}

/// Recognize an explicit distance matrix as a structured machine —
/// hierarchy (paper §5 future work), grid or torus; see
/// `model::topology::infer::infer_machine`.
fn cmd_infer(args: &Args) -> Result<()> {
    use qapmap::model::topology::infer::{infer_machine, InferError, InferredMachine};
    let path = args.options.get("matrix").ok_or_else(|| anyhow!("--matrix required"))?;
    let text = std::fs::read_to_string(path)?;
    let vals: Vec<u64> = text
        .split_whitespace()
        .map(|t| t.parse::<u64>().map_err(|e| anyhow!("bad entry {t:?}: {e}")))
        .collect::<Result<_>>()?;
    let n = (vals.len() as f64).sqrt() as usize;
    if n * n != vals.len() {
        bail!("{} entries is not a square matrix", vals.len());
    }
    match infer_machine(n, &vals) {
        Ok(InferredMachine::Hier(h)) => {
            let s: Vec<String> = h.s.iter().map(|x| x.to_string()).collect();
            let d: Vec<String> = h.d.iter().map(|x| x.to_string()).collect();
            println!("S = {}", s.join(":"));
            println!("D = {}", d.join(":"));
            println!("({} PEs, {} levels)", h.n_pes(), h.levels());
            Ok(())
        }
        Ok(m) => {
            let machine = m.into_machine();
            println!("machine = {}", machine.spec().map_err(|e| anyhow!(e))?);
            println!("({} PEs, {})", machine.n_pes(), machine.kind());
            Ok(())
        }
        Err(InferError::Mixed { hierarchy, lattice }) => bail!(
            "matrix matches no structured machine family:\n  \
             hierarchy: {hierarchy:?}\n  lattice: {lattice}\n\
             use --explicit-distances to map against the raw matrix"
        ),
        Err(e) => bail!("inference failed: {e:?} — use --explicit-distances instead"),
    }
}

fn cmd_verify(args: &Args) -> Result<()> {
    let seed: u64 = args.get_as("seed", 1);
    let mut rng = Rng::new(seed);
    let comm = load_comm(args, &mut rng)?;
    let n = comm.n();
    let (machine, resolution) = machine_for(args, n)?;
    let job = MapJobBuilder::for_machine(comm, machine)
        .machine_resolution(resolution)
        .algorithm_name(args.get("algo", "topdown"))
        .map_err(|e| anyhow!(e))?
        .seed(seed)
        .partition_config(PartitionConfig::perfectly_balanced())
        .verify(VerifyPolicy::Required)
        .build()
        .map_err(|e| anyhow!(e))?;
    let rt = RuntimeHandle::spawn_default()?;
    let mut session = MapSession::with_runtime(job, Some(rt));
    // run_checked distinguishes "could not verify" (runtime error, nothing
    // fits) from a clean verdict; both MATCH and MISMATCH come back Ok
    let report = session.run_checked().map_err(|e| anyhow!(e))?;
    report.mapping.validate().map_err(|e| anyhow!(e))?;
    match (report.xla_objective, report.verified) {
        (Some(xj), Some(ok)) => {
            println!("sparse (exact integer): {}", report.objective);
            println!("dense  (XLA f32):       {xj}");
            println!("{}", if ok { "MATCH" } else { "MISMATCH" });
            if !ok {
                bail!("verification failed");
            }
        }
        _ => bail!("instance (n={n}) larger than all artifacts"),
    }
    Ok(())
}
