"""AOT path tests: lowering to HLO text must succeed, be deterministic, and
contain no Mosaic custom-calls (which the CPU PJRT plugin cannot execute)."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_objective():
    spec = model.example_args(16)["objective"]
    text = aot.to_hlo_text(model.objective, spec)
    assert "HloModule" in text
    assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into HLO"
    assert "ENTRY" in text


def test_to_hlo_text_deterministic():
    spec = model.example_args(16)["objective"]
    t1 = aot.to_hlo_text(model.objective, spec)
    t2 = aot.to_hlo_text(model.objective, spec)
    assert t1 == t2


@pytest.mark.parametrize("key", ["objective", "objective_batch", "swap_gains"])
def test_all_entry_points_lower(key):
    fn = {
        "objective": model.objective,
        "objective_batch": model.objective_batch,
        "swap_gains": model.swap_gains,
    }[key]
    spec = model.example_args(32, batch=4)[key]
    text = aot.to_hlo_text(fn, spec)
    assert "HloModule" in text


def test_build_all_writes_artifacts(tmp_path):
    # shrink the matrix for test speed
    orig = aot.ARTIFACTS
    aot.ARTIFACTS = [("qap_obj", model.objective, [16], None)]
    try:
        written = aot.build_all(str(tmp_path))
    finally:
        aot.ARTIFACTS = orig
    assert len(written) == 1
    assert os.path.exists(written[0])
    content = open(written[0]).read()
    assert "HloModule" in content


def test_objective_entry_point_numerics():
    # run the L2 entry point end-to-end (jit, interpret-mode pallas inside)
    n = 16
    rng = np.random.default_rng(0)
    C = rng.integers(0, 5, (n, n)).astype(np.float32)
    C = np.triu(C, 1)
    C = C + C.T
    D = np.where(np.eye(n) > 0, 0.0, 7.0).astype(np.float32)
    sigma = jnp.asarray(rng.permutation(n).astype(np.int32))
    j = model.objective(jnp.asarray(C), jnp.asarray(D), sigma)
    # flat distances: J = 7 * total edge weight
    np.testing.assert_allclose(j, 7.0 * np.triu(C, 1).sum(), rtol=1e-6)
