"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps sizes, dtype-representable weight ranges, seeds and
permutations; this is the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qap, ref


def random_instance(n: int, seed: int, max_w: int = 50):
    """Symmetric zero-diagonal C and hierarchy-like D, plus a permutation."""
    rng = np.random.default_rng(seed)
    C = rng.integers(0, max_w, size=(n, n)).astype(np.float32)
    C = np.triu(C, 1)
    C = C + C.T
    # hierarchy-ish distances: distance by top bits, symmetric, zero diag
    levels = rng.choice([1.0, 10.0, 100.0], size=(n, n)).astype(np.float32)
    D = np.triu(levels, 1)
    D = D + D.T
    sigma = rng.permutation(n).astype(np.int32)
    return jnp.asarray(C), jnp.asarray(D), jnp.asarray(sigma)


# ---------------------------------------------------------------- matmul --

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_jnp(n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((n, n)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), dtype=jnp.float32)
    got = qap.matmul(a, b)
    want = a @ b
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_rectangular():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 64)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 16)), dtype=jnp.float32)
    np.testing.assert_allclose(qap.matmul(a, b), a @ b, rtol=1e-5, atol=1e-5)


def test_matmul_explicit_small_block():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((64, 64)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 64)), dtype=jnp.float32)
    np.testing.assert_allclose(qap.matmul(a, b, block=16), a @ b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- weighted sum --

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**31 - 1))
def test_weighted_sum_matches_jnp(n, seed):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.standard_normal((n, n)), dtype=jnp.float32)
    r = jnp.asarray(rng.standard_normal((n, n)), dtype=jnp.float32)
    np.testing.assert_allclose(
        qap.weighted_sum(c, r), jnp.sum(c * r), rtol=1e-4, atol=1e-4
    )


# -------------------------------------------------------------- objective --

@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_objective_kernel_matches_ref(n, seed):
    C, D, sigma = random_instance(n, seed)
    got = qap.qap_objective(C, D, sigma)
    want = ref.objective_ref(C, D, sigma)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_objective_onehot_formulation_equivalent():
    C, D, sigma = random_instance(32, 7)
    a = ref.objective_ref(C, D, sigma)
    b = ref.objective_onehot_ref(C, D, sigma)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_objective_identity_vs_manual():
    # 4-node path graph, unit distances except one far pair
    C = np.zeros((4, 4), np.float32)
    for (u, v, w) in [(0, 1, 3), (1, 2, 5), (2, 3, 2)]:
        C[u, v] = C[v, u] = w
    D = np.full((4, 4), 10.0, np.float32)
    D[np.arange(4), np.arange(4)] = 0
    D[0, 1] = D[1, 0] = 1.0
    D[2, 3] = D[3, 2] = 1.0
    sigma = jnp.arange(4, dtype=jnp.int32)
    got = qap.qap_objective(jnp.asarray(C), jnp.asarray(D), sigma)
    # edges: (0,1): 3*1, (1,2): 5*10, (2,3): 2*1
    np.testing.assert_allclose(got, 3 + 50 + 2, rtol=1e-6)


def test_objective_invariant_under_sigma_relabel():
    # applying the same extra permutation to rows/cols of D compensated by
    # composing sigma leaves J unchanged
    C, D, sigma = random_instance(16, 3)
    tau = np.random.default_rng(4).permutation(16).astype(np.int32)
    Dp = D[tau][:, tau]
    inv = np.empty(16, np.int32)
    inv[tau] = np.arange(16, dtype=np.int32)
    j1 = qap.qap_objective(C, D, sigma)
    j2 = qap.qap_objective(C, jnp.asarray(Dp), jnp.asarray(inv)[sigma])
    np.testing.assert_allclose(j1, j2, rtol=1e-5)


# -------------------------------------------------------------- batching --

def test_objective_batch_matches_singles():
    from compile import model
    C, D, _ = random_instance(16, 5)
    rng = np.random.default_rng(6)
    sigmas = jnp.asarray(
        np.stack([rng.permutation(16) for _ in range(8)]).astype(np.int32)
    )
    batch = model.objective_batch(C, D, sigmas)
    singles = jnp.stack([qap.qap_objective(C, D, s) for s in sigmas])
    np.testing.assert_allclose(batch, singles, rtol=1e-5)


# ------------------------------------------------------------ swap gains --

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_swap_gains_match_bruteforce(n, seed):
    C, D, sigma = random_instance(n, seed)
    rng = np.random.default_rng(seed ^ 0xABCD)
    B = 8
    pairs = np.stack(
        [rng.choice(n, size=2, replace=False) for _ in range(B)]
    ).astype(np.int32)
    got = qap.swap_gains(C, D, sigma, jnp.asarray(pairs))
    want = np.array([
        ref.swap_gain_bruteforce(C, D, sigma, int(u), int(v)) for u, v in pairs
    ])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_swap_gains_ref_matches_bruteforce():
    C, D, sigma = random_instance(24, 11)
    pairs = jnp.asarray([[0, 1], [2, 20], [5, 13]], dtype=jnp.int32)
    fast = ref.swap_gains_ref(C, D, sigma, pairs)
    slow = np.array([
        ref.swap_gain_bruteforce(C, D, sigma, int(u), int(v)) for u, v in pairs
    ])
    np.testing.assert_allclose(fast, slow, rtol=1e-5, atol=1e-3)


def test_swap_gain_antisymmetric_after_swap():
    # applying a swap then evaluating the reverse swap gives the negated gain
    C, D, sigma = random_instance(16, 12)
    u, v = 3, 9
    g1 = float(ref.swap_gains_ref(C, D, sigma, jnp.asarray([[u, v]], dtype=jnp.int32))[0])
    swapped = sigma.at[u].set(sigma[v]).at[v].set(sigma[u])
    g2 = float(ref.swap_gains_ref(C, D, swapped, jnp.asarray([[u, v]], dtype=jnp.int32))[0])
    np.testing.assert_allclose(g1, -g2, rtol=1e-4, atol=1e-3)


# --------------------------------------------------------------- dtypes ---

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_objective_dtypes(dtype):
    if dtype == jnp.float64:
        jax.config.update("jax_enable_x64", True)
    try:
        C, D, sigma = random_instance(16, 13)
        C = C.astype(dtype)
        D = D.astype(dtype)
        got = qap.qap_objective(C, D, sigma)
        want = ref.objective_ref(C, D, sigma)
        np.testing.assert_allclose(got, want, rtol=1e-5)
        assert got.dtype == dtype
    finally:
        if dtype == jnp.float64:
            jax.config.update("jax_enable_x64", False)
