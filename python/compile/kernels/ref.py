"""Pure-jnp reference oracle for the dense QAP objective and swap gains.

This is the correctness anchor for the Pallas kernels (Layer 1): every
kernel in :mod:`compile.kernels.qap` must match these functions to float
tolerance, enforced by ``python/tests`` (pytest + hypothesis).

Conventions (matching the Rust side, see ``rust/src/mapping/objective.rs``):

* ``C`` is the symmetric dense communication matrix with zero diagonal.
* ``D`` is the symmetric dense PE-distance matrix with zero diagonal.
* ``sigma`` maps process ``u`` to PE ``sigma[u]`` (the paper's ``Pi^-1``).
* The objective counts every undirected edge once:
  ``J = 1/2 * sum_{u,v} C[u,v] * D[sigma[u], sigma[v]]``.
"""

import jax.numpy as jnp


def objective_ref(C, D, sigma):
    """QAP objective via direct gather: ``0.5 * sum(C * D[sigma][:, sigma])``."""
    Dp = D[sigma][:, sigma]
    return 0.5 * jnp.sum(C * Dp)


def objective_onehot_ref(C, D, sigma):
    """Same objective via the one-hot-permutation matmul formulation
    ``R = P D P^T`` — the MXU-shaped path the Pallas kernel implements."""
    n = C.shape[0]
    P = jnp.eye(n, dtype=C.dtype)[sigma]  # P[u, pe] = 1 iff sigma[u] == pe
    R = P @ D @ P.T
    return 0.5 * jnp.sum(C * R)


def swap_gains_ref(C, D, sigma, pairs):
    """Exact gains for a batch of candidate swaps.

    For pair ``(u, v)``: the change of ``J`` if processes ``u`` and ``v``
    exchange PEs; positive gain = objective decreases. The ``(u, v)`` edge
    itself is invariant under the swap (D symmetric), hence the correction
    term.
    """
    u = pairs[:, 0]
    v = pairs[:, 1]
    pu = sigma[u]
    pv = sigma[v]
    Cu = C[u]                      # (B, n)
    Cv = C[v]
    Dpu = D[pu][:, sigma]          # (B, n): D[pu, sigma[x]]
    Dpv = D[pv][:, sigma]
    # sum over ALL x of (C[u,x]-C[v,x]) (D[pv,px]-D[pu,px]); the x in {u,v}
    # terms contribute -2*C[u,v]*D[pu,pv] which must be added back.
    dense = jnp.sum((Cu - Cv) * (Dpv - Dpu), axis=1)
    corr = 2.0 * C[u, v] * D[pu, pv]
    delta = dense + corr
    return -delta


def swap_gain_bruteforce(C, D, sigma, u, v):
    """O(n^2) brute force: recompute J before and after the swap."""
    j_before = objective_ref(C, D, sigma)
    swapped = sigma.at[u].set(sigma[v]).at[v].set(sigma[u])
    j_after = objective_ref(C, D, swapped)
    return j_before - j_after
