"""Layer-1 Pallas kernels: dense QAP objective and batched swap gains.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper targets CPU
clusters; there is no GPU artifact to port. We reformulate its objective for
the TPU MXU instead: the permuted distance matrix ``R = P D P^T`` is two
``n x n`` matmuls over a one-hot permutation matrix (systolic-array food),
and the sparse-weighted reduction ``sum(C * R)`` fuses into the same kernel
on the VPU. BlockSpec expresses the HBM<->VMEM schedule: ``BLOCK x BLOCK``
tiles (128x128 at production sizes — the native MXU tile), a k-loop as the
innermost grid dimension, and an accumulator tile that lives in VMEM across
the k-steps.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes byte-identically (see /opt/xla-example/README.md).

VMEM footprint per grid step (production 128x128 f32 tiles): 3 input tiles +
1 accumulator = 4 * 64 KiB = 256 KiB << 16 MiB VMEM, leaving ~60x headroom
for double buffering; the analysis lives in DESIGN.md §7.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int) -> int:
    """Largest MXU-friendly tile that divides n (artifact sizes are powers
    of two, so this is 128 for n >= 128, else n itself)."""
    for b in (128, 64, 32, 16, 8):
        if n % b == 0 and b <= n:
            return b
    return n


# --------------------------------------------------------------------------
# Tiled matmul kernel: out = A @ B
# --------------------------------------------------------------------------

def _matmul_kernel(a_ref, b_ref, o_ref):
    """Grid (i, j, k): accumulate A[i,k] @ B[k,j] into O[i,j]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


def matmul(a, b, block: int | None = None):
    """Blocked Pallas matmul; block defaults to the MXU-friendly divisor."""
    n, k = a.shape
    k2, m = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bn = block or _pick_block(n)
    bk = block or _pick_block(k)
    bm = block or _pick_block(m)
    grid = (n // bn, m // bm, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), a.dtype),
        interpret=True,
    )(a, b)


# --------------------------------------------------------------------------
# Fused weighted-sum kernel: scalar = sum(C * R) over tiles
# --------------------------------------------------------------------------

def _wsum_kernel(c_ref, r_ref, o_ref):
    """Grid (i, j): accumulate sum(C_tile * R_tile) into a (1,1) scalar."""
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0] += jnp.sum(c_ref[...] * r_ref[...])


def weighted_sum(c, r, block: int | None = None):
    """``sum(C * R)`` as a tiled Pallas reduction."""
    n, m = c.shape
    bn = block or _pick_block(n)
    bm = block or _pick_block(m)
    grid = (n // bn, m // bm)
    out = pl.pallas_call(
        _wsum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), c.dtype),
        interpret=True,
    )(c, r)
    return out[0, 0]


# --------------------------------------------------------------------------
# QAP objective: J = 0.5 * sum(C * (P D P^T))
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block",))
def qap_objective(C, D, sigma, block: int | None = None):
    """Dense QAP objective with the one-hot matmul formulation.

    Args:
      C: (n, n) f32 symmetric communication matrix, zero diagonal.
      D: (n, n) f32 symmetric PE-distance matrix, zero diagonal.
      sigma: (n,) i32, process -> PE assignment (a permutation).
    Returns: scalar f32, counting each undirected edge once.
    """
    n = C.shape[0]
    P = jax.nn.one_hot(sigma, n, dtype=C.dtype)  # (n, n)
    T = matmul(P, D, block)                      # T[u, q]  = D[sigma[u], q]
    R = matmul(T, P.T, block)                    # R[u, v]  = D[sigma[u], sigma[v]]
    return 0.5 * weighted_sum(C, R, block)


# --------------------------------------------------------------------------
# Batched swap gains
# --------------------------------------------------------------------------

def _gain_kernel(cu_ref, cv_ref, dpu_ref, dpv_ref, corr_ref, o_ref):
    """Grid (b, j): row-blocked fused gain reduction for a batch of pairs.

    Per pair row: gain = -(sum_x (Cu-Cv)*(Dpv-Dpu) + corr).
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = -corr_ref[...]

    o_ref[...] += -jnp.sum(
        (cu_ref[...] - cv_ref[...]) * (dpv_ref[...] - dpu_ref[...]),
        axis=1,
    )


@jax.jit
def swap_gains(C, D, sigma, pairs):
    """Gains for a batch of candidate swaps (positive = improvement).

    Args:
      C, D: as in :func:`qap_objective`.
      sigma: (n,) i32 permutation.
      pairs: (B, 2) i32 process pairs.
    Returns: (B,) f32 gains.

    The gathers (rows of C, permuted rows of D) run in plain XLA (L2); the
    Pallas kernel fuses the subtract/multiply/reduce over row blocks.
    """
    n = C.shape[0]
    B = pairs.shape[0]
    u = pairs[:, 0]
    v = pairs[:, 1]
    pu = sigma[u]
    pv = sigma[v]
    Cu = C[u]              # (B, n)
    Cv = C[v]
    Dpu = D[pu][:, sigma]  # (B, n)
    Dpv = D[pv][:, sigma]
    corr = 2.0 * C[u, v] * D[pu, pv]  # (B,)

    bb = _pick_block(B)
    bn = _pick_block(n)
    grid = (B // bb, n // bn)
    return pl.pallas_call(
        _gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bn), lambda b, j: (b, j)),
            pl.BlockSpec((bb, bn), lambda b, j: (b, j)),
            pl.BlockSpec((bb, bn), lambda b, j: (b, j)),
            pl.BlockSpec((bb, bn), lambda b, j: (b, j)),
            pl.BlockSpec((bb,), lambda b, j: (b,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda b, j: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), C.dtype),
        interpret=True,
    )(Cu, Cv, Dpu, Dpv, corr)
