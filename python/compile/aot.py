"""AOT lowering: JAX -> HLO text artifacts for the Rust/PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from ``python/``).
Run by ``make artifacts`` only — never on the request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (name, entry point, sizes, batch)
ARTIFACTS = [
    ("qap_obj", model.objective, [64, 128, 256], None),
    ("qap_batch", model.objective_batch, [64, 128], 16),
    ("swap_gain", model.swap_gains, [64, 128, 256], 32),
]


def to_hlo_text(fn, args) -> str:
    """Lower a jitted function at concrete avals and emit HLO text."""
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, fn, sizes, batch in ARTIFACTS:
        for n in sizes:
            spec = model.example_args(n, batch or 16)
            key = {
                "qap_obj": "objective",
                "qap_batch": "objective_batch",
                "swap_gain": "swap_gains",
            }[name]
            text = to_hlo_text(fn, spec[key])
            path = os.path.join(out_dir, f"{name}_n{n}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            written.append(path)
            print(f"wrote {path} ({len(text)} chars)")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
