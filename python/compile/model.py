"""Layer-2 JAX model: the dense-QAP compute graph the Rust runtime executes.

Exports three entry points, each AOT-lowered by :mod:`compile.aot` to HLO
text that ``rust/src/runtime`` loads through PJRT:

* :func:`objective` — scalar QAP objective of one assignment.
* :func:`objective_batch` — objectives of a batch of candidate assignments
  (the coordinator's batched verification/scoring path).
* :func:`swap_gains` — gains of a batch of candidate swaps.

Everything calls the Layer-1 Pallas kernels in :mod:`compile.kernels.qap`,
so the whole stack lowers into one fused HLO module per entry point; Python
never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels import qap


def objective(C, D, sigma):
    """Scalar objective; see :func:`compile.kernels.qap.qap_objective`."""
    return qap.qap_objective(C, D, sigma)


def objective_batch(C, D, sigmas):
    """Objectives of ``sigmas`` (B, n) under shared ``C``/``D`` — vmapped
    over the Pallas kernel so the lowered module contains a single batched
    computation."""
    return jax.vmap(lambda s: qap.qap_objective(C, D, s))(sigmas)


def swap_gains(C, D, sigma, pairs):
    """Batched swap gains; see :func:`compile.kernels.qap.swap_gains`."""
    return qap.swap_gains(C, D, sigma, pairs)


def example_args(n: int, batch: int = 16):
    """ShapeDtypeStructs for AOT lowering at size ``n``."""
    f = jnp.float32
    i = jnp.int32
    mat = jax.ShapeDtypeStruct((n, n), f)
    return {
        "objective": (mat, mat, jax.ShapeDtypeStruct((n,), i)),
        "objective_batch": (mat, mat, jax.ShapeDtypeStruct((batch, n), i)),
        "swap_gains": (
            mat,
            mat,
            jax.ShapeDtypeStruct((n,), i),
            jax.ShapeDtypeStruct((batch, 2), i),
        ),
    }
